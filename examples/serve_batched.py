"""Batched serving example: continuous batching of generation requests
through the serve engine (mamba2 smoke model — O(1) decode state).

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.config import ServeConfig
from repro.configs import registry
from repro.models import build_model
from repro.runtime.serve import Request, ServeEngine


def main():
    cfg = registry.get_config("mamba2_130m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, cfg, ServeConfig(batch=8, max_seq=128), params)

    rng = np.random.default_rng(0)
    n_req = 16
    for i in range(n_req):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               rng.integers(4, 12)).astype(
                               np.int32),
                           max_new_tokens=16))
    t0 = time.time()
    done = eng.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)}/{n_req} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt len {len(r.prompt)} -> {r.out}")
    assert len(done) == n_req


if __name__ == "__main__":
    main()
