"""End-to-end training driver: train a small LM for a few hundred steps with
checkpointing, fault injection, and the straggler monitor.

CPU-scaled by default (a ~6M-param danube-family model, 300 steps, ~5 min);
pass --size 100m for the 100M-class config (what you would run on a TPU
slice) and --grad-sync ring to use the explicit ppermute ring collectives
when more than one device is available.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import time

import jax

from repro.config import ModelConfig, ParallelConfig, TrainConfig
from repro.models import build_model
from repro.runtime.train import SimulatedFailure, Trainer

SIZES = {
    "tiny": ModelConfig(name="lm-tiny", family="dense", num_layers=4,
                        d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
                        vocab_size=2048, attention="gqa"),
    "100m": ModelConfig(name="lm-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4, d_ff=3072,
                        vocab_size=32768, attention="gqa"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-sync", default="xla",
                    choices=["xla", "ring", "hierarchical"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", action="store_true",
                    help="simulate a node crash at step 60% through")
    args = ap.parse_args()

    cfg = SIZES[args.size]
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq, lr=3e-3,
                       warmup_steps=20, total_steps=args.steps,
                       ckpt_every=50, ckpt_dir=args.ckpt_dir,
                       ckpt_async=True, seed=0)
    par = ParallelConfig(remat="none", scan_layers=True,
                         grad_sync=args.grad_sync)
    mesh = None
    if args.grad_sync != "xla":
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    model = build_model(cfg, par, mesh=mesh)

    injector = None
    if args.inject_failure:
        fired = {"done": False}

        def injector(step):
            if step == int(args.steps * 0.6) and not fired["done"]:
                fired["done"] = True
                print(f"!! injecting node failure at step {step}")
                raise SimulatedFailure

    tr = Trainer(model, cfg, tcfg, par, mesh=mesh, failure_injector=injector)
    print(f"training {cfg.name}: {args.steps} steps, "
          f"batch {args.batch}x{args.seq}, grad_sync={args.grad_sync}")
    t0 = time.time()
    rep = tr.run()
    dt = time.time() - t0
    print(f"\nfirst losses: {[round(l, 3) for l in rep.losses[:5]]}")
    print(f"last  losses: {[round(l, 3) for l in rep.losses[-5:]]}")
    print(f"steps/s: {rep.steps_run / dt:.2f}   restarts: {rep.restarts}   "
          f"straggler events: {rep.straggler_events}")
    assert rep.losses[-1] < rep.losses[0], "loss did not improve"
    print("loss improved — end-to-end training OK")


if __name__ == "__main__":
    main()
