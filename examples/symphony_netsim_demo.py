"""Symphony walkthrough: reproduce the paper's core phenomenon end to end.

Renders ASCII timelines of step overlap for baseline vs Symphony on the
Table-1 workload, plus the two-flow hardware-prototype scenario (Fig. 9).

  PYTHONPATH=src python examples/symphony_netsim_demo.py
"""
import numpy as np

from repro.core.netsim import (SimParams, WorkloadBuilder, make_leaf_spine,
                               metrics, simulate)


def sparkline(xs, width=72):
    blocks = " .:-=+*#%@"
    xs = np.asarray(xs, float)
    if len(xs) > width:
        xs = xs[np.linspace(0, len(xs) - 1, width).astype(int)]
    hi = max(xs.max(), 1)
    return "".join(blocks[min(int(v / hi * (len(blocks) - 1)), 9)] for v in xs)


def main():
    topo = make_leaf_spine(32, 4, 4)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(32)), ring_size=8, chunk_bytes=8e6,
                   passes=6, barrier=False)
    wl = b.build()
    cfg = SimParams(n_ticks=160_000, window=64)
    ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)

    print("Multiple 1-D Ring AllReduce, 32 nodes, chunk 8 MB (paper Table 1)")
    print(f"theoretical CCT (lockstep): {ideal*1e3:.0f} ms\n")
    for name, c in [("baseline (DCQCN+ECMP)", cfg),
                    ("symphony", cfg._replace(sym_on=True))]:
        res = simulate(topo, wl, c, routing="ecmp", seed=3)
        t, ov = metrics.overlap_series(res, c)
        cct = metrics.cct_seconds(res, wl, c)[0]
        cct_s = f"{cct*1e3:6.0f} ms" if np.isfinite(cct) else "  (unfinished)"
        print(f"{name:22s} CCT={cct_s}  max overlap={ov.max()}")
        print(f"  overlap timeline |{sparkline(ov)}|")
    print("\nFig. 9 scenario: flows A (late, step k) and B (step k+1), one port")
    b2 = WorkloadBuilder()
    b2.add_chain_job(pairs=[(0, 2), (1, 2)], steps=1, chunk_bytes=2.5e8,
                     step_offsets=[0, 1], flow_starts=[0.125, 0.0])
    topo2 = make_leaf_spine(4, 2, 2)
    wl2 = b2.build()
    c2 = SimParams(n_ticks=int(1.0 / 20e-6), dt=20e-6, window=8)
    for name, cc in [("baseline", c2), ("symphony", c2._replace(sym_on=True))]:
        res = simulate(topo2, wl2, cc, routing="balanced", seed=0)
        ft = np.asarray(res.finish_ticks) * cc.dt
        print(f"  {name:10s} flow A finishes {ft[0]*1e3:6.1f} ms, "
              f"flow B {ft[1]*1e3:6.1f} ms")


if __name__ == "__main__":
    main()
