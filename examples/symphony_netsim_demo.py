"""Symphony walkthrough: reproduce the paper's core phenomenon end to end.

Renders ASCII timelines of step overlap for baseline vs Symphony on the
Table-1 workload, plus the two-flow hardware-prototype scenario (Fig. 9),
and closes with the generalized stack: a 3-tier multi-pod fat-tree running
ring vs halving-doubling vs hierarchical allreduce.

  PYTHONPATH=src python examples/symphony_netsim_demo.py
"""
import numpy as np

from repro.core.netsim import (SimParams, WorkloadBuilder, make_fat_tree,
                               make_leaf_spine, metrics, simulate)


def sparkline(xs, width=72):
    blocks = " .:-=+*#%@"
    xs = np.asarray(xs, float)
    if len(xs) > width:
        xs = xs[np.linspace(0, len(xs) - 1, width).astype(int)]
    hi = max(xs.max(), 1)
    return "".join(blocks[min(int(v / hi * (len(blocks) - 1)), 9)] for v in xs)


def main():
    topo = make_leaf_spine(32, 4, 4)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(32)), ring_size=8, chunk_bytes=8e6,
                   passes=6, barrier=False)
    wl = b.build()
    cfg = SimParams(n_ticks=160_000, window=64)
    ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)

    print("Multiple 1-D Ring AllReduce, 32 nodes, chunk 8 MB (paper Table 1)")
    print(f"theoretical CCT (lockstep): {ideal*1e3:.0f} ms\n")
    for name, c in [("baseline (DCQCN+ECMP)", cfg),
                    ("symphony", cfg._replace(sym_on=True))]:
        res = simulate(topo, wl, c, routing="ecmp", seed=3)
        t, ov = metrics.overlap_series(res, c)
        cct = metrics.cct_seconds(res, wl, c)[0]
        cct_s = f"{cct*1e3:6.0f} ms" if np.isfinite(cct) else "  (unfinished)"
        print(f"{name:22s} CCT={cct_s}  max overlap={ov.max()}")
        print(f"  overlap timeline |{sparkline(ov)}|")
    print("\nFig. 9 scenario: flows A (late, step k) and B (step k+1), one port")
    b2 = WorkloadBuilder()
    b2.add_chain_job(pairs=[(0, 2), (1, 2)], steps=1, chunk_bytes=2.5e8,
                     step_offsets=[0, 1], flow_starts=[0.125, 0.0])
    topo2 = make_leaf_spine(4, 2, 2)
    wl2 = b2.build()
    c2 = SimParams(n_ticks=int(1.0 / 20e-6), dt=20e-6, window=8)
    for name, cc in [("baseline", c2), ("symphony", c2._replace(sym_on=True))]:
        res = simulate(topo2, wl2, cc, routing="balanced", seed=0)
        ft = np.asarray(res.finish_ticks) * cc.dt
        print(f"  {name:10s} flow A finishes {ft[0]*1e3:6.1f} ms, "
              f"flow B {ft[1]*1e3:6.1f} ms")

    print("\n3-tier fat-tree (2 pods x 2 ToRs x 4 hosts, 1:2 core tier):"
          " collective algorithms")
    ft3 = make_fat_tree(n_pods=2, tors_per_pod=2, spines_per_pod=2,
                        hosts_per_tor=4, core_oversubscription=2.0)
    hosts = list(range(ft3.n_hosts))
    workloads = []
    b = WorkloadBuilder()
    b.add_ring_job(hosts=hosts, ring_size=8, chunk_bytes=2e6, passes=1)
    workloads.append(("ring (2x8)", b.build()))
    b = WorkloadBuilder()
    b.add_halving_doubling_job(hosts=hosts, chunk_bytes=2e6)
    workloads.append(("halving-doubling", b.build()))
    b = WorkloadBuilder()
    b.add_hierarchical_job(hosts=hosts, group_size=4, chunk_bytes=2e6)
    workloads.append(("hierarchical", b.build()))
    for name, w in workloads:
        ideal3 = metrics.ideal_cct(w, 0, 10e9 / 8)
        c3 = SimParams(n_ticks=int(ideal3 * 8 / 10e-6), window=32,
                       sym_on=True)
        res = simulate(ft3, w, c3, routing="ecmp", seed=1)
        cct = metrics.cct_seconds(res, w, c3)[0]
        cct_s = f"{cct*1e3:6.1f} ms" if np.isfinite(cct) else "(unfinished)"
        print(f"  {name:18s} CCT={cct_s}  (lockstep bound "
              f"{ideal3*1e3:5.1f} ms)")


if __name__ == "__main__":
    main()
