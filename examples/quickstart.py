"""Quickstart: the three layers of the repo in two minutes.

1. Symphony's switch logic on a synthetic packet trace (the paper's Alg. 1)
2. a small network simulation showing the baseline snowball + the fix
3. a tiny LM forward/backward through the shared model substrate

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------- 1. Alg. 1
from repro.core.symphony import (SymphonyParams, init_state,
                                 process_packet_batch)

print("=== 1. Symphony switch state machine ===")
rng = np.random.default_rng(0)
n = 400
steps = np.minimum(np.arange(n) // 50 + rng.integers(0, 3, n), 7)
psns = rng.integers(1, 2000, n)
lasts = rng.random(n) < 0.02
state, marks = process_packet_batch(
    init_state(), jnp.asarray(steps, jnp.int32),
    jnp.asarray(psns, jnp.float32), jnp.asarray(lasts),
    jnp.asarray(rng.random(n), jnp.float32), SymphonyParams())
print(f"processed {n} packets: step_min={int(state.step_min)}, "
      f"marked {int(marks.sum())} outpacing packets")

# ---------------------------------------------------------------- 2. netsim
from repro.core.netsim import (SimParams, WorkloadBuilder, make_leaf_spine,
                               metrics, simulate)

print("\n=== 2. ring-collective network simulation (Table 1, small) ===")
topo = make_leaf_spine(16, 2, 2)
b = WorkloadBuilder()
b.add_ring_job(hosts=list(range(16)), ring_size=8, chunk_bytes=2e6,
               passes=3, barrier=False)
wl = b.build()
cfg = SimParams(n_ticks=30_000, window=32)
ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)
for name, c, routing in [("baseline (ECMP)", cfg, "ecmp"),
                         ("symphony", cfg._replace(sym_on=True), "ecmp")]:
    res = simulate(topo, wl, c, routing=routing, seed=4)
    cct = metrics.cct_seconds(res, wl, c)[0]
    print(f"  {name:18s} CCT={cct*1e3:7.1f} ms (ideal {ideal*1e3:.1f}) "
          f"max step overlap={metrics.max_overlap(res, c)}")

# ---------------------------------------------------------------- 3. models
from repro.configs import registry
from repro.models import build_model

print("\n=== 3. model substrate (jamba smoke config) ===")
mcfg = registry.get_config("jamba-v0.1-52b", smoke=True)
model = build_model(mcfg)
params = model.init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                            mcfg.vocab_size)
logits, aux = model.apply(params, tokens)
print(f"  hybrid (mamba+attn+moe) forward: logits {logits.shape}, "
      f"aux loss {float(aux):.4f}")
print("done.")
