"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, 384 experts top-8 — trillion-parameter MoE (paper-table config).
[arXiv:2501.kimi2]

Note: the assignment specifies GQA kv=8 (not MLA) and a uniform 61-layer MoE
stack; we follow the assignment numbers exactly.
"""
from ..config import LM_SHAPES, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=163840,
    attention="gqa",
    activation="swiglu",
    moe=MoEConfig(num_experts=384, experts_per_token=8, d_ff_expert=2048,
                  capacity_factor=1.25),
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="kimi-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    attention="gqa",
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff_expert=64,
                  capacity_factor=1.5),
    tie_embeddings=False,
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": "pure full attention; skipped per assignment rule"}
