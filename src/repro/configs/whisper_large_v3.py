"""whisper-large-v3 [audio]: enc-dec 32+32L d_model=1280 20H d_ff=5120
vocab=51866; conv/mel frontend is a STUB (encoder consumes precomputed frame
embeddings). [arXiv:2212.04356]"""
from ..config import LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,               # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    attention="gqa",
    activation="gelu",
    norm="layernorm",
    pos_emb="learned",
    max_position=448,
    frontend="audio_stub",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    num_layers=2,
    encoder_layers=2,
    encoder_seq=64,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    attention="gqa",
    activation="gelu",
    norm="layernorm",
    pos_emb="learned",
    max_position=64,
    frontend="audio_stub",
)

SHAPES = LM_SHAPES
SKIPS = {
    "long_500k": "enc-dec full attention; decoder max positions 448 — "
                 "skipped per assignment rule",
}
# decode_32k keeps a 32k decoder self-attention cache structurally (a
# perf shape beyond the model's trained 448 positions; noted in DESIGN.md).
