"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000; squared-ReLU MLP. [arXiv:2402.16819]"""
from ..config import LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    attention="gqa",
    activation="relu2",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="nemotron15-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    attention="gqa",
    activation="relu2",
    tie_embeddings=False,
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": "pure full attention; skipped per assignment rule"}
