"""Registry of the 10 assigned architectures (+ the paper's netsim config).

Each module exposes CONFIG (the exact assigned full config), SMOKE (a reduced
same-family config for CPU smoke tests), and SHAPES (the assigned input-shape
cells, with skips noted).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "mamba2_130m",
    "minicpm3_4b",
    "h2o_danube_3_4b",
    "nemotron_4_15b",
    "nemotron_4_340b",
    "granite_moe_1b_a400m",
    "kimi_k2_1t_a32b",
    "whisper_large_v3",
    "jamba_v0_1_52b",
    "qwen2_vl_2b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


def canonical(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return name


def get_module(name: str):
    return importlib.import_module(f"repro.configs.{canonical(name)}")


def get_config(name: str, smoke: bool = False):
    mod = get_module(name)
    return mod.SMOKE if smoke else mod.CONFIG


def get_shapes(name: str):
    return get_module(name).SHAPES


def all_cells():
    """Yield (arch, ShapeSpec, skip_reason|None) for the 40 assigned cells."""
    for a in ARCHS:
        mod = get_module(a)
        for spec in mod.SHAPES:
            skip = mod.SKIPS.get(spec.name) if hasattr(mod, "SKIPS") else None
            yield a, spec, skip
