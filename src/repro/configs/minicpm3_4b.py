"""minicpm3-4b [dense]: 62L d_model=2560 40H MLA d_ff=6400 vocab=73448.
[hf:openbmb/MiniCPM3-4B]"""
from ..config import LM_SHAPES, MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=96,                  # qk_nope + qk_rope
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    activation="swiglu",
    logit_softcap=0.0,
)

SMOKE = ModelConfig(
    name="minicpm3-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=48,
    d_ff=256,
    vocab_size=512,
    attention="mla",
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": "pure full attention (MLA): O(S^2) prefill; skipped per "
                      "assignment rule, noted in DESIGN.md"}
