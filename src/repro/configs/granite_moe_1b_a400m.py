"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) expert d_ff=512
vocab=49155, 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from ..config import LM_SHAPES, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=0,                      # every FFN is MoE
    vocab_size=49155,
    attention="gqa",
    activation="swiglu",
    moe=MoEConfig(num_experts=32, experts_per_token=8, d_ff_expert=512,
                  capacity_factor=1.25),
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    attention="gqa",
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff_expert=64,
                  capacity_factor=1.5),
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": "pure full attention; skipped per assignment rule"}
