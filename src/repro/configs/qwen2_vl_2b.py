"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936;
M-RoPE, dynamic resolution.  Vision frontend is a STUB (prefill consumes
precomputed patch embeddings + (t,h,w) position triples). [arXiv:2409.12191]"""
from ..config import LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    attention="gqa",
    activation="swiglu",
    pos_emb="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    frontend="vision_stub",
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke",
    family="vlm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attention="gqa",
    pos_emb="mrope",
    mrope_sections=(4, 6, 6),
    frontend="vision_stub",
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": "pure full attention; skipped per assignment rule"}
