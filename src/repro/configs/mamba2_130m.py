"""mamba2-130m [ssm]: 24L d_model=768, attention-free SSD, vocab 50280,
ssm_state=128.  [arXiv:2405.21060]"""
from ..config import LM_SHAPES, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                      # pure mamba blocks, no MLP
    vocab_size=50280,
    attention="none",
    pos_emb="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=128),
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    num_layers=2,
    d_model=128,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    attention="none",
    pos_emb="none",
    ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=32, chunk_size=32),
)

SHAPES = LM_SHAPES
SKIPS: dict[str, str] = {}       # SSM: long_500k runs (constant state)
