"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000; squared-ReLU MLP.  Needs FSDP x TP to fit v5e HBM.
[arXiv:2402.16819]"""
from ..config import LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    attention="gqa",
    activation="relu2",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="nemotron340-smoke",
    family="dense",
    num_layers=2,
    d_model=192,
    num_heads=6,
    num_kv_heads=2,
    d_ff=768,
    vocab_size=512,
    attention="gqa",
    activation="relu2",
    tie_embeddings=False,
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": "pure full attention; skipped per assignment rule"}
