"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; Mamba+attention 1:7 interleave, MoE 16 experts top-2 on every
other layer. [arXiv:2403.19887]"""
from ..config import LM_SHAPES, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    attention="gqa",
    attn_every=8,                # 1 attention layer per 8 (1:7 ratio)
    activation="swiglu",
    moe=MoEConfig(num_experts=16, experts_per_token=2, d_ff_expert=14336,
                  capacity_factor=1.25),
    moe_every=2,                 # MoE on every other layer
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk_size=128),
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    num_layers=8,                # one full period
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attention="gqa",
    attn_every=8,
    moe=MoEConfig(num_experts=4, experts_per_token=2, d_ff_expert=128,
                  capacity_factor=1.5),
    moe_every=2,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk_size=32),
)

SHAPES = LM_SHAPES
SKIPS: dict[str, str] = {}  # hybrid SSM: long_500k runs
