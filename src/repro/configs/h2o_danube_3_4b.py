"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000; llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from ..config import LM_SHAPES, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    attention="gqa",
    sliding_window=8192,
    activation="swiglu",
)

SMOKE = ModelConfig(
    name="danube-smoke",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    attention="gqa",
    sliding_window=64,
)

SHAPES = LM_SHAPES
SKIPS: dict[str, str] = {}  # SWA is sub-quadratic: long_500k runs
