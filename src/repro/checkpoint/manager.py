"""Mesh-agnostic checkpointing: async, atomic, keep-k, CRC-verified,
elastic-restore (a checkpoint written on one mesh restores onto another).

Layout:  <dir>/step_<n>/
           manifest.json   {step, tree structure, shapes, dtypes, crcs,
                            data_state, rng, config fingerprint}
           <leaf-path>.npy one file per pytree leaf (host numpy)

Writes go to step_<n>.tmp then rename (atomic on POSIX).  `restore` reshapes
nothing — shapes are mesh-independent because we store the *global* array;
resharding onto the restore mesh happens via jax.device_put with the target
sharding (elastic restarts change only the sharding).
"""
from __future__ import annotations

import json
import shutil
import threading
import zlib
from pathlib import Path

import jax
import numpy as np


def jnp_astype(arr, dtype):
    import jax.numpy as jnp
    return jnp.asarray(arr).astype(dtype)


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], prefix + (str(k),))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            yield from _flatten(v, prefix + (str(i),))
    elif hasattr(tree, "_fields"):          # NamedTuple
        for k in tree._fields:
            yield from _flatten(getattr(tree, k), prefix + (k,))
    elif tree is None:
        return
    else:
        yield prefix, tree


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, extra: dict | None = None):
        """Snapshot to host memory synchronously, write in background."""
        leaves = [(path, np.asarray(x)) for path, x in _flatten(tree)]
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, leaves, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, leaves, extra or {})

    def _write(self, step: int, leaves, extra: dict):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for path, arr in leaves:
            name = "__".join(path) or "root"
            dtype = str(arr.dtype)
            if dtype == "bfloat16":   # numpy can't round-trip ml_dtypes
                arr = arr.view(np.uint16)
            np.save(tmp / f"{name}.npy", arr)
            manifest["leaves"][name] = {
                "shape": list(arr.shape), "dtype": dtype,
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                      if p.is_dir() and p.name.startswith("step_")
                      and not p.name.endswith(".tmp"))

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, shardings=None, verify=True):
        """Restore into the structure of `like_tree` (ShapeDtypeStructs or
        arrays).  `shardings`: matching pytree of NamedShardings for elastic
        restore onto a (possibly different) mesh."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like = list(_flatten(like_tree))
        sh_flat = dict(_flatten(shardings)) if shardings is not None else {}
        out = {}
        for path, like in flat_like:
            name = "__".join(path) or "root"
            arr = np.load(d / f"{name}.npy")
            meta = manifest["leaves"][name]
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc"]:
                    raise IOError(f"checkpoint corruption in {name}")
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(f"shape mismatch {name}: "
                                 f"{arr.shape} vs {like.shape}")
            sh = sh_flat.get(path)
            if str(arr.dtype) != str(like.dtype):
                arr = np.asarray(jnp_astype(arr, like.dtype))
            out[path] = jax.device_put(arr, sh) if sh is not None else arr
        return _unflatten_like(like_tree, out), manifest["extra"]


def _unflatten_like(like, flat: dict, prefix=()):
    if isinstance(like, dict):
        return {k: _unflatten_like(v, flat, prefix + (str(k),))
                for k, v in like.items()}
    if hasattr(like, "_fields"):
        return type(like)(**{k: _unflatten_like(getattr(like, k), flat,
                                                prefix + (k,))
                             for k in like._fields})
    if isinstance(like, (list, tuple)):
        return type(like)(_unflatten_like(v, flat, prefix + (str(i),))
                          for i, v in enumerate(like))
    if like is None:
        return None
    return flat[prefix]
