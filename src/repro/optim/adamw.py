"""AdamW with cosine schedule, global-norm clipping, and mixed-precision
optimizer state (bf16 m/v for >=300B models — halves optimizer HBM)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..config import TrainConfig


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict
    master: dict | None      # fp32 master weights (optional)


def cosine_lr(cfg: TrainConfig):
    def lr(step):
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        t = jnp.clip((step - cfg.warmup_steps) /
                     jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return lr


def init_opt_state(params: dict, cfg: TrainConfig) -> OptState:
    sdtype = jnp.dtype(cfg.opt_state_dtype)
    # .copy() breaks XLA constant dedup: m and v must be distinct buffers or
    # donating the state trips "donate the same buffer twice".
    zeros = lambda p: jnp.zeros(p.shape, sdtype).copy()
    master = None
    if cfg.master_weights:
        master = jax.tree.map(lambda p: p.astype(jnp.float32).copy(), params)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    master=master)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params: dict, grads: dict, state: OptState,
                 cfg: TrainConfig) -> tuple[dict, OptState, dict]:
    """Returns (new_params, new_state, metrics)."""
    lr = cosine_lr(cfg)(state.step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    b1, b2 = cfg.adam_b1, cfg.adam_b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)
    sdtype = jnp.dtype(cfg.opt_state_dtype)

    def upd(p, g, m, v, mw):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        base = mw if mw is not None else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.adam_eps)
                           + cfg.weight_decay * base)
        return new, m32.astype(sdtype), v32.astype(sdtype)

    leaves_p, tdef = jax.tree.flatten(params)
    leaves_g = jax.tree.leaves(grads)
    leaves_m = jax.tree.leaves(state.m)
    leaves_v = jax.tree.leaves(state.v)
    leaves_w = jax.tree.leaves(state.master) if state.master is not None \
        else [None] * len(leaves_p)
    new_p, new_m, new_v, new_w = [], [], [], []
    for p, g, m, v, w in zip(leaves_p, leaves_g, leaves_m, leaves_v, leaves_w):
        n, m2, v2 = upd(p, g, m, v, w)
        new_w.append(n)
        new_p.append(n.astype(p.dtype))
        new_m.append(m2)
        new_v.append(v2)
    master = jax.tree.unflatten(tdef, new_w) if state.master is not None else None
    return (jax.tree.unflatten(tdef, new_p),
            OptState(step=step, m=jax.tree.unflatten(tdef, new_m),
                     v=jax.tree.unflatten(tdef, new_v), master=master),
            {"lr": lr, "grad_norm": gnorm})
