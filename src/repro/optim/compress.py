"""Error-feedback gradient compression for the inter-pod (DCN) hop.

int8 block quantization with a persistent residual: the quantization error is
re-added to the next step's gradient, so compression bias vanishes in
expectation (standard EF-SGD argument).  Cuts the pod<->pod wire bytes 4x —
exactly the hop whose contention Symphony manages.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 1024


class Int8Meta(NamedTuple):
    scale: jax.Array     # [nblocks] fp32 per-block scale


def encode_int8(x: jax.Array) -> tuple[jax.Array, Int8Meta]:
    """x: [n] fp32 -> (int8-in-fp32 container, meta).  The values stay in a
    float container because the ring all-reduce sums them (sum of int8 fits
    fp32 exactly up to 2^16 pods)."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xp / scale[:, None]), -127, 127)
    return q.reshape(-1), Int8Meta(scale=scale)


def decode_int8(q: jax.Array, meta: Int8Meta) -> jax.Array:
    xp = q.reshape(-1, BLOCK) * meta.scale[:, None]
    return xp.reshape(-1)


def ef_compress_update(grad_flat: jax.Array, residual: jax.Array
                       ) -> tuple[jax.Array, jax.Array, Int8Meta]:
    """Apply error feedback: g' = g + residual; quantize; new residual =
    g' - dequant(quant(g'))."""
    g = grad_flat + residual
    q, meta = encode_int8(g)
    deq = decode_int8(q, meta)
    return q, g - deq, meta
