"""Trace-time feature flags.

ROOFLINE_MODE: XLA's HLO cost analysis counts while-loop bodies ONCE
(not x trip-count), so any lax.scan/lax.map in the program under-reports
FLOPs/bytes.  For the roofline lowering we therefore trace a semantically
identical but loop-free program: unrolled layer stacks, no gradient
accumulation, unchunked cross-entropy / attention / SSD / MoE dispatch.
Memory analysis keeps using the production (scanned) lowering.
"""
ROOFLINE_MODE = False

# §Perf hillclimb levers (trace-time):
SSD_BF16 = False        # bf16 intra-chunk SSD intermediates (halves the
                        # [Q,Q]/[Q,N] HBM traffic of the reference SSD)
RING_SYNC_DTYPE = "float32"   # explicit-ring gradient reduction dtype


def set_roofline(v: bool) -> None:
    global ROOFLINE_MODE
    ROOFLINE_MODE = bool(v)


def set_ssd_bf16(v: bool) -> None:
    global SSD_BF16
    SSD_BF16 = bool(v)


def set_ring_sync_dtype(d: str) -> None:
    global RING_SYNC_DTYPE
    RING_SYNC_DTYPE = d
