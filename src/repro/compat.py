"""Portability shims for jax APIs that moved/renamed after the 0.4.x line.

The training/serving stack targets current jax (``jax.shard_map`` with
``check_vma``/``axis_names``, ``jax.sharding.AxisType``,
``jax.lax.axis_size``); this module maps those onto the older spellings
(``jax.experimental.shard_map`` with ``check_rep``/``auto``, no axis types,
``psum(1, axis)``) so the same code runs on both.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType  # noqa: F401  (jax >= 0.5)
    _NEW = True
except ImportError:
    AxisType = None
    _NEW = False


def make_mesh(shape, axes):
    """``jax.make_mesh`` with every axis in Auto mode where supported."""
    if _NEW:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` without replication checking.

    ``axis_names`` restricts manual mode to those axes (the rest stay
    automatic); on old jax this is expressed through the ``auto`` set.
    """
    if _NEW:
        kw = {"check_vma": False}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {"check_rep": False}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def axis_size(axis) -> int:
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)   # classic idiom: folds to a static int


def manual_axes() -> set[str]:
    """Mesh axes that are Manual in the current trace (inside shard_map)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.axis_names:
            return set()
        return {n for n, t in zip(am.axis_names, am.axis_types)
                if "Manual" in str(t)}
    import jax.core as core
    try:   # on 0.4.x the bound axis names are exactly the manual axes
        return set(core.unsafe_get_axis_names_DO_NOT_USE())
    except Exception:
        return set()
