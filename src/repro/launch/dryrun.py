import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes, record memory/cost analysis + collective bytes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --out results.json

Results are cached per (arch, shape, mesh) in the output JSON; finished cells
are skipped on re-run (resumable).
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "dryrun_results.json"

# v5e hardware constants (roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (~per direction)
HBM_BYTES = 16 * 1024**3



def _cost_dict(ca):
    """compiled.cost_analysis() returns a dict on current jax, [dict] on 0.4.x."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}

def collective_bytes(hlo: str) -> dict:
    """Sum operand bytes of collective ops in compiled HLO, grouped by kind,
    with ring-cost wire-byte estimates per chip."""
    DT = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3fn": 1,
          "f8e5m2": 1, "s16": 2, "u16": 2, "c64": 8}
    out: dict[str, dict] = {}
    # result type(s) appear right after '=' for the collective op
    pat = re.compile(
        r"= ((?:\(?)(?:[a-z0-9]+\[[0-9,]*\][^ )]*(?:, )?)+\)?) "
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(-start|-done)?\(")
    grp = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
    grp_iota = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    for line in hlo.splitlines():
        m = pat.search(line)
        if not m or (m.group(3) == "-done"):
            continue
        kind = m.group(2)
        tensors = re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", m.group(1))
        nbytes = 0
        for dt, dims in tensors:
            sz = 1
            for d in dims.split(","):
                if d:
                    sz *= int(d)
            nbytes += sz * DT.get(dt, 4)
        # group size for ring cost factors
        n = None
        g = grp.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g = grp_iota.search(line)
            if g:
                n = int(g.group(2))
        n = n or 1
        if kind == "all-reduce":
            wire = 2 * nbytes * (n - 1) / max(n, 1)
        elif kind in ("all-gather",):
            wire = nbytes * (n - 1) / max(n, 1)   # nbytes = result (gathered)
        elif kind in ("reduce-scatter", "all-to-all"):
            wire = nbytes * (n - 1) / max(n, 1)
        else:  # collective-permute
            wire = nbytes
        d = out.setdefault(kind, {"count": 0, "bytes": 0.0, "wire": 0.0})
        d["count"] += 1
        d["bytes"] += float(nbytes)
        d["wire"] += float(wire)
    return out


def _full_params(cfg):
    from ..models import build_model
    from ..models.params import count_params
    from .steps import active_param_count
    n = count_params(build_model(cfg).param_spec())
    return n, active_param_count(cfg, n)


def _measure(arch, shape_name, mesh, overrides, depth):
    from .steps import build_cell
    cell = build_cell(arch, shape_name, mesh, policy_overrides=overrides,
                      depth_override=depth)
    with mesh:
        jitted = jax.jit(cell.fn, donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return cell, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False, roofline: bool = False) -> dict:
    """Lower + compile one cell.

    roofline=True measures a loop-free variant (unrolled layers, accum=1,
    unchunked CE/attention/SSD/MoE) because XLA cost analysis counts
    while-loop bodies once.  To keep unrolled compiles tractable, costs are
    measured at depths of 1 and 2 layer-groups and extrapolated with the
    exact linear model cost(G) = c + d*G (stacks are homogeneous per group;
    optimizer/param-proportional terms are linear in G too).  Memory
    analysis always comes from the production (scanned) lowering.
    """
    from .. import flags
    from ..configs import registry as _reg
    from .mesh import make_production_mesh
    from .steps import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if roofline:
        flags.set_roofline(True)
        try:
            cfg = _reg.get_config(arch)
            from ..models import build_model
            model = build_model(cfg)
            period = getattr(model, "period", 1)
            G = cfg.num_layers // period if period else cfg.num_layers
            overrides = {"scan_layers": False, "accum": 1}
            cell, c1 = _measure(arch, shape_name, mesh, overrides, period)
            _, c2 = _measure(arch, shape_name, mesh, overrides, 2 * period)

            def costs(comp):
                ca = _cost_dict(comp.cost_analysis())
                colls = collective_bytes(comp.as_text())
                return (float(ca.get("flops", 0.0)),
                        float(ca.get("bytes accessed", 0.0)),
                        sum(d["wire"] for d in colls.values()), colls)

            f1, b1, w1, _ = costs(c1)
            f2, b2, w2, colls2 = costs(c2)

            def extrap(v1, v2):
                # exact linear model; if XLA restructured ops between depths
                # (slope <= 0), fall back to proportional scaling from the
                # 2-group measurement.
                if v2 > v1 > 0:
                    return v1 + (v2 - v1) * (G - 1)
                return v2 / 2.0 * G

            flops_dev = extrap(f1, f2)
            bytes_dev = extrap(b1, b2)
            wire_dev = extrap(w1, w2)
            t_all = time.time() - t0
            return {
                "arch": arch, "shape": shape_name,
                "mesh": list(mesh.devices.shape), "chips": mesh.size,
                "lower_s": 0.0, "compile_s": round(t_all, 1),
                "flops_per_device": flops_dev,
                "bytes_per_device": bytes_dev,
                "wire_bytes_per_device": wire_dev,
                "collectives": colls2,
                "extrapolated": {"groups": G, "period": period,
                                 "g1": [f1, b1, w1], "g2": [f2, b2, w2]},
                "memory": {"argument": 0, "output": 0, "alias": 0, "temp": 0,
                           "per_device_total": 0, "fits_v5e": True,
                           "note": "see production lowering record"},
                "model_params": _full_params(cfg)[0],
                "active_params": _full_params(cfg)[1],
                "t_compute": flops_dev / PEAK_FLOPS,
                "t_memory": bytes_dev / HBM_BW,
                "t_collective": wire_dev / ICI_BW,
                "ok": True,
            }
        finally:
            flags.set_roofline(False)

    cell = build_cell(arch, shape_name, mesh)
    with mesh:
        jitted = jax.jit(cell.fn, donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled.cost_analysis())
        hlo = compiled.as_text()
    colls = collective_bytes(hlo)
    n_chips = mesh.size
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    wire_dev = sum(d["wire"] for d in colls.values())
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collectives": colls,
        "wire_bytes_per_device": wire_dev,
        "memory": {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "per_device_total": int(per_dev_bytes),
            "fits_v5e": bool(per_dev_bytes <= HBM_BYTES),
        },
        "model_params": cell.model_params,
        "active_params": cell.active_params,
        # roofline terms (seconds) — see EXPERIMENTS.md §Roofline
        "t_compute": flops_dev / PEAK_FLOPS,
        "t_memory": bytes_dev / HBM_BW,
        "t_collective": wire_dev / ICI_BW,
        "ok": True,
    }
    if keep_hlo:
        res["hlo_len"] = len(hlo)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--roofline", action="store_true",
                    help="loop-free lowering for exact cost analysis "
                         "(single-pod; stored under key suffix /roofline)")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.roofline:
        args.mesh = "single"

    from ..configs import registry

    out_path = Path(args.out)
    results = json.loads(out_path.read_text()) if out_path.exists() else {}

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    for arch, spec, skip in registry.all_cells():
        if args.arch and registry.canonical(args.arch) != arch:
            continue
        if args.shape and spec.name != args.shape:
            continue
        cells.append((arch, spec, skip))

    for arch, spec, skip in cells:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            if args.roofline:
                mesh_name = "roofline"
            key = f"{arch}/{spec.name}/{mesh_name}"
            if skip:
                results[key] = {"arch": arch, "shape": spec.name,
                                "skipped": skip, "ok": True}
                print(f"[skip] {key}: {skip}")
                continue
            if key in results and results[key].get("ok") and not args.force:
                print(f"[cached] {key}")
                continue
            print(f"[run] {key} ...", flush=True)
            try:
                res = run_cell(arch, spec.name, mp, roofline=args.roofline)
                print(f"  ok: compile={res['compile_s']}s "
                      f"mem/dev={res['memory']['per_device_total']/2**30:.2f}GiB "
                      f"t_comp={res['t_compute']*1e3:.2f}ms "
                      f"t_mem={res['t_memory']*1e3:.2f}ms "
                      f"t_coll={res['t_collective']*1e3:.2f}ms", flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                res = {"arch": arch, "shape": spec.name, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"  FAIL: {res['error'][:200]}", flush=True)
            results[key] = res
            out_path.write_text(json.dumps(results, indent=1))
    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells ok -> {out_path}")


if __name__ == "__main__":
    main()
