"""Production mesh builders.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); 'pod' is outer
data parallelism over the DCN tier — the Ethernet fabric whose ring-step
misalignment Symphony manages (core/netsim simulates exactly this tier).

These are FUNCTIONS so importing the module never touches jax device state.
"""
from __future__ import annotations

import jax

from ..compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return _compat_make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist (tests / examples): 1D 'data' mesh."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
