"""Train / prefill / decode step builders + ShapeDtypeStruct input specs for
every assigned (architecture x shape) cell.

`build_cell(arch, shape_name, mesh, ...)` returns a `Cell` whose `fn` +
`args` are ready for ``jax.jit(fn).lower(*args)`` — the multi-pod dry-run and
the roofline harness both consume this.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..config import ModelConfig, ParallelConfig, ShapeSpec, TrainConfig
from ..configs import registry
from ..models import build_model
from ..models.params import abstract_tree
from ..optim.adamw import OptState, adamw_update, init_opt_state
from ..parallel.sharding import make_rules, spec_for

# ---------------------------------------------------------------------------
# per-arch parallel/training policy (iterated during §Perf)

ARCH_POLICY: dict[str, dict[str, Any]] = {
    "mamba2_130m":         dict(fsdp=False, remat="block",
                                opt_dtype="float32", master=True, accum=1),
    "minicpm3_4b":         dict(fsdp=False, remat="block",
                                opt_dtype="float32", master=True, accum=2),
    "h2o_danube_3_4b":     dict(fsdp=False, remat="block",
                                opt_dtype="float32", master=True, accum=2),
    "nemotron_4_15b":      dict(fsdp=True, remat="block",
                                opt_dtype="float32", master=True, accum=4),
    "nemotron_4_340b":     dict(fsdp=True, remat="block",
                                opt_dtype="bfloat16", master=False, accum=16),
    "granite_moe_1b_a400m": dict(fsdp=False, remat="block",
                                 opt_dtype="float32", master=True, accum=2),
    "kimi_k2_1t_a32b":     dict(fsdp=True, remat="block",
                                opt_dtype="bfloat16", master=False, accum=16),
    "whisper_large_v3":    dict(fsdp=True, remat="block",
                                opt_dtype="float32", master=True, accum=8),
    "jamba_v0_1_52b":      dict(fsdp=True, remat="full",
                                opt_dtype="bfloat16", master=False, accum=8),
    "qwen2_vl_2b":         dict(fsdp=False, remat="block",
                                opt_dtype="float32", master=True, accum=1),
}


def make_parallel_config(arch: str, shape_name: str) -> ParallelConfig:
    pol = ARCH_POLICY[registry.canonical(arch)]
    return ParallelConfig(fsdp=pol["fsdp"], remat=pol["remat"],
                          scan_layers=True, grad_sync="xla",
                          seq_shard_decode=shape_name.startswith("long"))


def make_train_config(arch: str, spec: ShapeSpec) -> TrainConfig:
    pol = ARCH_POLICY[registry.canonical(arch)]
    return TrainConfig(global_batch=spec.global_batch, seq_len=spec.seq_len,
                       opt_state_dtype=pol["opt_dtype"],
                       master_weights=pol["master"])


# ---------------------------------------------------------------------------


@dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    fn: Callable                  # jit-able step function
    args: tuple                   # ShapeDtypeStructs (dry-run) or arrays
    donate: tuple[int, ...]       # argnums to donate
    model_params: int             # true (unpadded) parameter count
    active_params: int            # active params per token (MoE-aware)
    notes: str = ""


def _sh(mesh, *parts):
    return NamedSharding(mesh, P(*parts))


def _batch_axes(mesh: Mesh, batch: int):
    """Largest prefix of (pod, data) that divides batch."""
    axes = []
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape and batch % (n * mesh.shape[a]) == 0:
            axes.append(a)
            n *= mesh.shape[a]
    return tuple(axes) if axes else None


def _kv_seq_axes(mesh: Mesh, shape_name: str, batch_axes):
    """Decode KV caches shard their sequence axis over 'model' (+ idle data
    axes for long-context): distributed flash-decode."""
    axes = ["model"]
    used = set(batch_axes or ())
    if shape_name.startswith("long"):
        for a in ("data", "pod"):
            if a in mesh.shape and a not in used:
                axes.insert(0, a)
    return tuple(axes)


def active_param_count(cfg: ModelConfig, total: int) -> int:
    """Active params per token: subtract unrouted expert weights."""
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = m.d_ff_expert * cfg.d_model * \
        (3 if cfg.activation == "swiglu" else 2)
    n_moe = sum(1 for i in range(cfg.num_layers) if cfg.is_moe_layer(i))
    inactive = n_moe * (m.num_experts - m.experts_per_token) * per_expert
    return total - inactive


# ---------------------------------------------------------------------------


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               abstract: bool = True, policy_overrides: dict | None = None,
               depth_override: int | None = None) -> Cell:
    import dataclasses
    arch = registry.canonical(arch)
    cfg = registry.get_config(arch)
    if depth_override is not None:
        repl = {"num_layers": depth_override}
        if cfg.encoder_layers:
            repl["encoder_layers"] = depth_override
        cfg = dataclasses.replace(cfg, **repl)
    spec = next(s for s in registry.get_shapes(arch) if s.name == shape_name)
    par = make_parallel_config(arch, shape_name)
    tcfg = make_train_config(arch, spec)
    pol = dict(ARCH_POLICY[arch])
    if policy_overrides:
        pol.update(policy_overrides)
        par = ParallelConfig(**{**par.__dict__, **{
            k: v for k, v in policy_overrides.items()
            if k in ParallelConfig.__dataclass_fields__}})
    rules = make_rules(fsdp=par.fsdp, seq_shard_decode=par.seq_shard_decode)
    model = build_model(cfg, par, mesh=mesh, rules=rules)

    from ..models.params import count_params
    n_params = count_params(build_model(cfg).param_spec())  # unpadded
    n_active = active_param_count(cfg, n_params)

    if spec.kind == "train":
        return _train_cell(arch, cfg, spec, tcfg, par, model, mesh, rules,
                           n_params, n_active, pol)
    if spec.kind == "prefill":
        return _prefill_cell(arch, cfg, spec, model, mesh, rules,
                             n_params, n_active)
    return _decode_cell(arch, cfg, spec, model, mesh, rules,
                        n_params, n_active)


# ------------------------------------------------------------ train


def _model_inputs(cfg: ModelConfig, spec: ShapeSpec, mesh: Mesh,
                  for_train: bool):
    """ShapeDtypeStructs for the forward inputs of this family."""
    B, S = spec.global_batch, spec.seq_len
    ba = _batch_axes(mesh, B)
    tok_sh = _sh(mesh, ba, None)
    if cfg.family == "encdec":
        # stub audio frontend: encoder frames are precomputed embeddings
        dec_S = min(S, 4096) if for_train else min(S, 4096)
        return {
            "tokens": jax.ShapeDtypeStruct((B, dec_S), jnp.int32,
                                           sharding=tok_sh),
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16,
                                           sharding=_sh(mesh, ba, None, None)),
        }
    if cfg.family == "vlm":
        return {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16,
                                           sharding=_sh(mesh, ba, None, None)),
            "positions": jax.ShapeDtypeStruct((B, S, 3), jnp.int32,
                                              sharding=_sh(mesh, ba, None, None)),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_sh)}


def _labels_spec(cfg: ModelConfig, spec: ShapeSpec, mesh: Mesh):
    B, S = spec.global_batch, spec.seq_len
    if cfg.family == "encdec":
        S = min(S, 4096)
    ba = _batch_axes(mesh, B)
    return jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=_sh(mesh, ba, None))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  chunk: int = 1024) -> jax.Array:
    """Sequence-chunked CE: bounds the fp32 softmax temporaries to
    [B, chunk, V] instead of materializing an fp32 copy of the full logits."""
    from .. import flags
    B, S, V = logits.shape
    if flags.ROOFLINE_MODE or S % chunk or S <= chunk:
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits, labels[..., None], axis=-1)[..., 0].astype(jnp.float32)
        return (lse - gold).mean()

    def body(acc, i):
        lg = jax.lax.dynamic_slice_in_dim(logits, i * chunk, chunk, axis=1)
        lb = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        lse = jax.nn.logsumexp(lg.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            lg, lb[..., None], axis=-1)[..., 0].astype(jnp.float32)
        return acc + (lse - gold).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(S // chunk))
    return total / (B * S)


def _train_cell(arch, cfg, spec, tcfg, par, model, mesh, rules,
                n_params, n_active, pol=None) -> Cell:
    p_abs = model.abstract_params()
    # ZeRO-1: optimizer states shard their 'embed'/'expert_mlp' axes over the
    # data axes even when weights are replicated there (policy zero1=True —
    # the §Perf alternative to FSDP that avoids per-microbatch parameter
    # all-gathers), and always over 'pod' on the multi-pod mesh.
    opt_rules = dict(rules)
    zero_axes = ["pod"] if "pod" in mesh.shape else []
    if (pol or {}).get("zero1"):
        zero_axes.append("data")
    for ax_name in zero_axes:
        for ax in ("embed", "expert_mlp"):
            cur = opt_rules.get(ax) or ()
            if ax_name not in cur:
                opt_rules[ax] = tuple(cur) + (ax_name,)

    sdtype = jnp.dtype(tcfg.opt_state_dtype)
    spec_tree = model.param_spec()
    opt_abs_f32 = abstract_tree(spec_tree, opt_rules, mesh)

    def recast(tree, dt):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, dt, sharding=x.sharding),
            tree)

    opt_abs = OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=recast(opt_abs_f32, sdtype),
        v=recast(opt_abs_f32, sdtype),
        master=recast(opt_abs_f32, jnp.float32) if tcfg.master_weights
        else None)
    inputs = _model_inputs(cfg, spec, mesh, for_train=True)
    labels = _labels_spec(cfg, spec, mesh)

    accum = (pol or ARCH_POLICY[arch]).get("accum", 1)
    B = spec.global_batch
    while accum > 1 and (B % accum or (B // accum) %
                         max(mesh.shape.get("data", 1) *
                             mesh.shape.get("pod", 1), 1)):
        accum //= 2   # keep microbatches shardable over the data axes

    def loss_fn(p, mb):
        if cfg.family == "encdec":
            logits, aux = model.apply(p, mb["tokens"], mb["frames"])
        elif cfg.family == "vlm":
            logits, aux = model.apply(p, positions=mb["positions"],
                                      embeds=mb["embeds"])
        else:
            logits, aux = model.apply(p, mb["tokens"])
        return cross_entropy(logits[..., :cfg.vocab_size],
                             mb["labels"]) + aux

    # accumulate in bf16 when the optimizer state is bf16 (>=300B models):
    # an fp32 accumulator for 1T params costs 16 GiB/chip by itself.
    acc_dtype = jnp.bfloat16 if tcfg.opt_state_dtype == "bfloat16" \
        else jnp.float32

    def train_step(params, opt, batch):
        if accum > 1:
            # gradient accumulation: microbatch the global batch to bound
            # live activations (the big-model policy)
            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def micro(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), grads_acc, grads)
                return (loss_acc + loss, grads), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero), mbs)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, metrics = adamw_update(params, grads, opt, tcfg)
        metrics["loss"] = loss
        return params, opt, metrics

    batch = dict(inputs, labels=labels)
    return Cell(arch=arch, shape=spec, fn=train_step,
                args=(p_abs, opt_abs, batch), donate=(0, 1),
                model_params=n_params, active_params=n_active)


# ------------------------------------------------------------ prefill


def _prefill_cell(arch, cfg, spec, model, mesh, rules, n_params, n_active
                  ) -> Cell:
    p_abs = model.abstract_params()
    inputs = _model_inputs(cfg, spec, mesh, for_train=False)

    def prefill_step(params, batch):
        if cfg.family == "encdec":
            logits, _ = model.apply(params, batch["tokens"], batch["frames"])
        elif cfg.family == "vlm":
            logits, _ = model.apply(params, positions=batch["positions"],
                                    embeds=batch["embeds"])
        else:
            logits, _ = model.apply(params, batch["tokens"])
        return logits[:, -1]

    return Cell(arch=arch, shape=spec, fn=prefill_step, args=(p_abs, inputs),
                donate=(), model_params=n_params, active_params=n_active)


# ------------------------------------------------------------ decode


def _abstract_cache(model, cfg, spec, mesh, shape_name, rules):
    """ShapeDtypeStructs for the decode cache with per-shape shardings."""
    B, S = spec.global_batch, spec.seq_len
    ba = _batch_axes(mesh, B)
    kv_axes = _kv_seq_axes(mesh, shape_name, ba)

    if cfg.family == "encdec":
        p_abs = model.abstract_params()
        enc_abs = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                       jnp.bfloat16)
        real = jax.eval_shape(lambda p, e: model.init_cache(p, e, S),
                              p_abs, enc_abs)

        def shard_ed(x):
            if len(x.shape) == 5 and x.shape[3] == S:    # self kv [L,B,H,S,hd]
                parts = (None, ba, None, kv_axes, None)
            elif len(x.shape) == 5:                      # cross [L,B,Senc,H,hd]
                parts = (None, ba, None, "model", None)
            else:
                parts = tuple([None] * len(x.shape))
            return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                        sharding=_sh(mesh, *parts))
        return jax.tree.map(shard_ed, real)

    # LM families: take structure from init_cache, attach shardings.
    real = jax.eval_shape(lambda: model.init_cache(B, S))

    def with_sharding(x):
        nd = len(x.shape)
        if nd == 5 and jnp.issubdtype(x.dtype, jnp.floating) and \
                x.dtype == jnp.bfloat16:
            parts = (None, ba, None, kv_axes, None)      # gqa kv [G,B,Hkv,S,hd]
        elif nd == 5:
            parts = (None, ba, "model", None, None)      # ssm state [G,B,H,hd,N]
        elif nd == 4 and cfg.ssm is not None and \
                x.shape[2] == cfg.ssm.d_conv - 1:
            parts = (None, ba, None, None)               # conv ring [G,B,K-1,C]
        elif nd == 4:
            parts = (None, ba, kv_axes, None)            # mla latent [G,B,S,r]
        else:
            parts = tuple([None] * nd)
        return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                    sharding=_sh(mesh, *parts))

    return jax.tree.map(with_sharding, real)


def _decode_cell(arch, cfg, spec, model, mesh, rules, n_params, n_active
                 ) -> Cell:
    B, S = spec.global_batch, spec.seq_len
    p_abs = model.abstract_params()
    ba = _batch_axes(mesh, B)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=_sh(mesh, ba, None))
    pos = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=_sh(mesh, ba))
    cache = _abstract_cache(model, cfg, spec, mesh, spec.name, rules)

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(params, cache, tokens, pos)
        return logits, new_cache

    return Cell(arch=arch, shape=spec, fn=serve_step,
                args=(p_abs, cache, tokens, pos), donate=(1,),
                model_params=n_params, active_params=n_active)
