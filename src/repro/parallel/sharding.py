"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Weights and activations carry *logical* axis names; a rules table maps them to
mesh axes.  The production mesh is ('data','model') intra-pod and
('pod','data','model') across pods ('pod' = outer data parallelism over the
DCN tier — exactly the fabric Symphony targets).

Divisibility policy: when a logical axis maps to mesh axes whose product does
not divide the dimension, the model pads the dimension up (standard
Megatron-style head/vocab padding).  `padded(n, tp)` computes that.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# weight rules -------------------------------------------------------------
BASE_RULES: dict[str, tuple[str, ...] | None] = {
    # weights
    "vocab": ("model",),
    "embed": None,               # FSDP overrides to ("data",)
    "heads": ("model",),
    "kv_heads": None,            # kv heads replicated under TP (vLLM-style)
    "head_dim": None,
    "mlp": ("model",),
    "experts": ("model",),       # expert parallelism
    "expert_mlp": None,
    "ssm_heads": ("model",),
    "ssm_inner": ("model",),
    "state": None,
    "conv": None,
    "q_lora": ("model",),
    "kv_lora": None,
    "layers": None,
    "norm": None,
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": ("model",),        # sequence-parallel residuals at remat
                                 # boundaries (Megatron-SP style)
    "kv_seq": None,              # decode KV cache; overridden for seq-sharding
    "act_embed": None,
    "act_heads": ("model",),
    "act_mlp": ("model",),
    "act_experts": ("model",),
}


def make_rules(*, fsdp: bool = False, seq_shard_decode: bool = False,
               overrides: Mapping[str, tuple[str, ...] | None] | None = None
               ) -> dict[str, tuple[str, ...] | None]:
    rules = dict(BASE_RULES)
    if fsdp:
        rules["embed"] = ("data",)
        rules["expert_mlp"] = ("data",)
    if seq_shard_decode:
        rules["kv_seq"] = ("data",)
    if overrides:
        rules.update(overrides)
    return rules


def mesh_axis_size(mesh: Mesh, axes: tuple[str, ...] | None) -> int:
    if not axes:
        return 1
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def padded(n: int, tp: int) -> int:
    """Round n up to a multiple of tp."""
    return int(-(-n // tp) * tp)


def spec_for(axes: Sequence[str | None],
             rules: Mapping[str, tuple[str, ...] | None],
             mesh: Mesh) -> P:
    """Logical axes -> PartitionSpec, dropping mesh axes absent in `mesh`
    (so the same rules serve single-pod and multi-pod meshes)."""
    parts = []
    used: set[str] = set()
    for a in axes:
        m = rules.get(a) if a is not None else None
        if m is None:
            parts.append(None)
            continue
        keep = tuple(x for x in m if x in mesh.shape and x not in used)
        used.update(keep)
        parts.append(keep if len(keep) > 1 else (keep[0] if keep else None))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_for(axes: Sequence[str | None],
                 rules: Mapping[str, tuple[str, ...] | None],
                 mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(axes, rules, mesh))


def _manual_axes() -> set[str]:
    """Mesh axes that are Manual in the current trace (inside shard_map):
    with_sharding_constraint may not reference them."""
    from ..compat import manual_axes
    return manual_axes()


def constrain(x: jax.Array, axes: Sequence[str | None],
              rules: Mapping[str, tuple[str, ...] | None] | None,
              mesh: Mesh | None) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without mesh/rules);
    silently drops mesh axes that are manual in the surrounding shard_map
    (the ring-grad-sync trainer runs the model under manual data axes)."""
    if mesh is None or rules is None or mesh.size == 1:
        return x
    manual = _manual_axes()
    if manual:
        rules = {k: (tuple(a for a in v if a not in manual) or None)
                 if v is not None else None for k, v in rules.items()}
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes, rules, mesh)))
