"""Pipeline parallelism over the 'pod' axis (GPipe schedule, shard_map).

The multi-pod mesh's outer axis can run as pipeline stages instead of data
parallelism: each pod holds a contiguous slice of layers; microbatches
stream through a ppermute ring between stages.  The schedule is the
classic GPipe fill-drain: with S stages and M microbatches the bubble
fraction is (S-1)/(M+S-1).

This is an optional mapping (default multi-pod config uses hierarchical DP,
which rooflines better for the assigned shapes — see EXPERIMENTS.md); it
exists to demonstrate and test the PP plumbing the framework would need at
1000+ nodes, where DCN bandwidth per pod can favour activations-over-DCN
(PP) against gradients-over-DCN (DP).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(layer_fn: Callable, n_stages: int, microbatches: int,
                   axis: str = "pod"):
    """Build a pipelined stack applier running under shard_map manual over
    `axis`.

    layer_fn(stage_params, x) -> x applies THIS stage's layer slice.
    Returns fn(stage_params, x_local) where x_local is the full batch
    (replicated over the pipeline axis); output is the final stage's result
    broadcast back to all stages.
    """

    def apply(stage_params, x):
        stage = jax.lax.axis_index(axis)
        n = n_stages
        B = x.shape[0]
        assert B % microbatches == 0, (B, microbatches)
        mb = B // microbatches
        xs = x.reshape(microbatches, mb, *x.shape[1:])
        n_ticks = microbatches + n - 1
        perm = [(i, (i + 1) % n) for i in range(n)]

        def tick(carry, t):
            acc, inflight = carry
            # which microbatch enters stage 0 at tick t
            take = jnp.where(t < microbatches, t, 0)
            enter = xs[take]
            cur = jnp.where(stage == 0, enter, inflight)
            out = layer_fn(stage_params, cur)
            # the last stage completes microbatch (t - n + 1) at tick t
            done_idx = t - (n - 1)
            acc = jax.lax.cond(
                done_idx >= 0,
                lambda a: a.at[jnp.maximum(done_idx, 0)].set(out),
                lambda a: a, acc)
            nxt = jax.lax.ppermute(out, axis, perm)
            return (acc, nxt), None

        acc0 = jnp.zeros_like(xs)
        inflight0 = jnp.zeros_like(xs[0])
        (acc, _), _ = jax.lax.scan(tick, (acc0, inflight0),
                                   jnp.arange(n_ticks))
        # acc holds final outputs only on the last stage; broadcast them
        out = acc.reshape(B, *x.shape[1:])
        is_last = (stage == n - 1).astype(out.dtype)
        out = jax.lax.psum(out * is_last, axis)
        return out

    return apply


def run_pipelined(mesh: Mesh, layer_fn: Callable, stage_params, x,
                  microbatches: int = 4, axis: str = "pod"):
    """Convenience wrapper: stage_params has a leading [n_stages] axis that
    is split over `axis`; x is replicated."""
    n = mesh.shape[axis]
    fn = pipeline_apply(layer_fn, n, microbatches, axis)
    from ..compat import shard_map
    sm = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P())
    return sm(stage_params, x)
