"""Configuration system: model / parallelism / training / serving configs.

Every assigned architecture is a `ModelConfig` in `repro.configs.<id>`;
`repro.configs.registry` maps ``--arch`` ids to them.  Configs are frozen
dataclasses so they hash (usable as jit static args) and serialize to JSON
for checkpoints / launch manifests.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    shared_expert_d_ff: int = 0        # kimi/granite style shared expert
    first_k_dense: int = 0             # first k layers use dense FFN
    aux_loss_coef: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (state-space duality) block parameters."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128
    # derived: d_inner = expand * d_model; n_heads = d_inner // head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads
    # attention flavor
    attention: str = "gqa"             # gqa | mla | none
    sliding_window: int = 0            # 0 = full attention
    mla: MLAConfig | None = None
    # mlp
    activation: str = "swiglu"         # swiglu | relu2 | gelu
    # moe
    moe: MoEConfig | None = None
    moe_every: int = 1                 # MoE layer period (jamba: 2)
    # ssm / hybrid
    ssm: SSMConfig | None = None
    attn_every: int = 0                # hybrid: 1 attention layer per this many
                                       # (jamba: 8 -> layers 7, 15, ... are attn)
    # positions / embeddings
    rope_theta: float = 1e4
    pos_emb: str = "rope"              # rope | mrope | learned | none
    mrope_sections: tuple[int, ...] = (16, 24, 24)   # qwen2-vl t/h/w split
    max_position: int = 131072
    tie_embeddings: bool = True
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500            # encoder positions (stub frontend output)
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    logit_softcap: float = 0.0
    # numerics
    dtype: str = "bfloat16"
    # frontend stubs ([audio]/[vlm]): inputs are precomputed embeddings
    frontend: str = "none"             # none | audio_stub | vision_stub

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    def layer_kind(self, layer_idx: int) -> str:
        """'attn' | 'ssm' for the mixer at this depth (hybrid interleave)."""
        if self.family == "ssm":
            return "ssm"
        if self.attn_every:
            return "attn" if (layer_idx % self.attn_every) == self.attn_every - 1 \
                else "ssm"
        return "attn"

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        if layer_idx < self.moe.first_k_dense:
            return False
        return (layer_idx % self.moe_every) == self.moe_every - 1 \
            if self.moe_every > 1 else True


@dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the mesh (axes: pod?, data, model)."""
    fsdp: bool = False                 # shard weights over 'data' too (ZeRO-3)
    shard_embed_data: bool = True      # FSDP detail: embedding over data axis
    remat: str = "none"                # none | block | full
    scan_layers: bool = True
    grad_sync: str = "xla"             # xla | ring (explicit ppermute rings)
    ring_buckets: int = 4              # gradient buckets for ring grad-sync
    ring_bidirectional: bool = False
    compress_interpod: bool = False    # int8 error-feedback across 'pod'
    seq_shard_decode: bool = True      # shard KV cache over 'data' for decode


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    opt_state_dtype: str = "float32"   # bfloat16 for >=300B models
    master_weights: bool = True        # keep fp32 master copy
    seed: int = 0
    # checkpointing / resilience
    ckpt_every: int = 100
    ckpt_keep: int = 3
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 128
    max_seq: int = 32768
    prefill_chunk: int = 2048


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned (arch x shape) cell."""
    name: str                          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                          # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES = (
    ShapeSpec("train_4k", "train", 4096, 256),
    ShapeSpec("prefill_32k", "prefill", 32768, 32),
    ShapeSpec("decode_32k", "decode", 32768, 128),
    ShapeSpec("long_500k", "decode", 524288, 1),
)


def to_json(cfg: Any) -> str:
    def enc(o):
        if dataclasses.is_dataclass(o):
            return dataclasses.asdict(o)
        raise TypeError(o)
    return json.dumps(cfg, default=enc, indent=2, sort_keys=True)


def param_count(cfg: ModelConfig) -> int:
    """Closed-form parameter count (validated against built params in tests)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    total = cfg.vocab_size * d                     # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d
    n_layers = cfg.num_layers + cfg.encoder_layers

    def attn_params():
        if cfg.attention == "mla":
            m = cfg.mla
            p = d * m.q_lora_rank
            p += m.q_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += cfg.num_heads * m.v_head_dim * d
            p += m.q_lora_rank + m.kv_lora_rank   # latent rmsnorms
            return p
        q = d * cfg.num_heads * hd
        kv = 2 * d * cfg.num_kv_heads * hd
        o = cfg.num_heads * hd * d
        return q + kv + o

    def mlp_params(layer):
        if cfg.is_moe_layer(layer):
            m = cfg.moe
            per = m.d_ff_expert * d * (3 if cfg.activation == "swiglu" else 2)
            p = m.num_experts * per + d * m.num_experts      # router
            if m.shared_expert_d_ff:
                p += m.shared_expert_d_ff * d * (3 if cfg.activation == "swiglu" else 2)
            return p
        return cfg.d_ff * d * (3 if cfg.activation == "swiglu" else 2)

    def ssm_params():
        s = cfg.ssm
        d_in = s.expand * d
        nh = d_in // s.head_dim
        p = d * (2 * d_in + 2 * s.d_state + nh)    # in_proj (x,z,B,C,dt)
        p += s.d_conv * (d_in + 2 * s.d_state)     # conv over x,B,C
        p += nh * 3                                # dt_bias, A_log, D
        p += d_in                                  # gated rmsnorm
        p += d_in * d                              # out_proj
        return p

    nf = 2 if cfg.norm == "layernorm" else 1        # layernorm has a bias
    if cfg.family == "encdec":
        # decoder: self-attn + cross-attn + mlp + 3 norms
        total += cfg.num_layers * (2 * attn_params() + mlp_params(0)
                                   + 3 * d * nf)
        # encoder: attn + mlp + 2 norms
        total += cfg.encoder_layers * (attn_params() + mlp_params(0)
                                       + 2 * d * nf)
        total += (cfg.max_position + cfg.encoder_seq) * d   # learned pos
        total += 2 * d * nf                         # enc_norm + final norm
        return int(total)
    for layer in range(cfg.num_layers):
        kind = cfg.layer_kind(layer)
        total += attn_params() if kind == "attn" else ssm_params()
        total += mlp_params(layer)
        has_mlp = bool(cfg.d_ff) or cfg.is_moe_layer(layer)
        total += (2 * d if has_mlp else d) * nf     # ln1 (+ ln2 with an FFN)
    total += d * nf                                 # final norm
    return int(total)
