"""Symphony core: the paper's contribution (Alg. 1 + network simulation)."""
from .symphony import (Packet, SymphonyParams, SymphonyState, init_state,
                       marking_probability, process_packet,
                       process_packet_batch, window_update)

__all__ = [
    "Packet", "SymphonyParams", "SymphonyState", "init_state",
    "marking_probability", "process_packet", "process_packet_batch",
    "window_update",
]
