"""Leaf-spine fabric model for the Symphony network simulator.

Link indexing is arithmetic so flow routes are tiny integer tuples instead of
a dense incidence matrix:

  [0,              H)                 host  h -> ToR(h)      (access up)
  [H,              2H)                ToR(h) -> host h       (access down)
  [2H,             2H + T*S)          ToR t -> spine s       (uplink,   t*S+s)
  [2H + T*S,       2H + 2*T*S)        spine s -> ToR t       (downlink, s*T+t)

Hosts are assigned to ToRs contiguously (hosts_per_tor = H / T).  An optional
oversubscription factor scales ToR<->spine capacity down relative to access
links, modeling the paper's 1:2-1:8 multi-pod interconnects (§4.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

DEFAULT_LINK_BPS = 10e9 / 8.0  # 10 Gbps in bytes/s (paper §4.1)


@dataclass(frozen=True)
class Topology:
    n_hosts: int
    n_tors: int
    n_spines: int
    link_cap: np.ndarray          # [L] bytes/s
    symphony_mask: np.ndarray     # [L] bool — ports running Symphony (ToR egress)

    @property
    def hosts_per_tor(self) -> int:
        return self.n_hosts // self.n_tors

    @property
    def n_links(self) -> int:
        return int(self.link_cap.shape[0])

    # ---- link index helpers (host/tor/spine ids -> link id) ----
    def acc_up(self, host: np.ndarray) -> np.ndarray:
        return np.asarray(host)

    def acc_down(self, host: np.ndarray) -> np.ndarray:
        return self.n_hosts + np.asarray(host)

    def uplink(self, tor: np.ndarray, spine: np.ndarray) -> np.ndarray:
        return 2 * self.n_hosts + np.asarray(tor) * self.n_spines + np.asarray(spine)

    def downlink(self, spine: np.ndarray, tor: np.ndarray) -> np.ndarray:
        return 2 * self.n_hosts + self.n_tors * self.n_spines \
            + np.asarray(spine) * self.n_tors + np.asarray(tor)

    def tor_of(self, host: np.ndarray) -> np.ndarray:
        return np.asarray(host) // self.hosts_per_tor


def make_leaf_spine(
    n_hosts: int = 32,
    n_tors: int = 4,
    n_spines: int = 4,
    link_bps: float = DEFAULT_LINK_BPS,
    oversubscription: float = 1.0,
) -> Topology:
    """Build the paper's default 4 ToR x 4 spine, 32-host fabric (Table 1).

    ``oversubscription`` > 1 shrinks fabric (ToR<->spine) capacity: a value of
    4 models a 1:4 oversubscribed tier.
    """
    if n_hosts % n_tors:
        raise ValueError(f"hosts ({n_hosts}) must divide evenly over ToRs ({n_tors})")
    n_fabric = 2 * n_tors * n_spines
    L = 2 * n_hosts + n_fabric
    cap = np.full(L, link_bps, np.float64)
    cap[2 * n_hosts:] = link_bps * (n_hosts / n_tors) / n_spines / oversubscription \
        if oversubscription != 1.0 else link_bps
    # Symphony runs on ToR egress ports: uplinks (ToR->spine) and access-down
    # (ToR->host) — §5 "Practical deployment": ToR-only is sufficient.
    mask = np.zeros(L, bool)
    mask[n_hosts:2 * n_hosts] = True            # ToR -> host
    mask[2 * n_hosts: 2 * n_hosts + n_tors * n_spines] = True  # ToR -> spine
    return Topology(n_hosts=n_hosts, n_tors=n_tors, n_spines=n_spines,
                    link_cap=cap, symphony_mask=mask)


def scale_for_hosts(n_hosts: int, link_bps: float = DEFAULT_LINK_BPS,
                    oversubscription: float = 1.0) -> Topology:
    """Paper-style scaling: 8 hosts per ToR; spines sized to keep the fabric
    non-blocking at oversubscription=1 (S = hosts_per_tor)."""
    n_tors = max(2, n_hosts // 8)
    n_spines = max(2, min(8, n_hosts // n_tors))
    return make_leaf_spine(n_hosts, n_tors, n_spines, link_bps, oversubscription)
