"""Generic link-table fabric models for the Symphony network simulator.

A :class:`Topology` is a flat table of directed links plus enough structure
to (a) enumerate the ECMP candidate paths of any host pair and (b) map every
link to the switch that owns its egress port (for Symphony deployment).

Concrete fabrics:

* :class:`LeafSpine` — the paper's 2-tier fabric (Table 1).  Link indexing is
  arithmetic so flow routes are tiny integer tuples:

    [0,              H)                 host  h -> ToR(h)      (access up)
    [H,              2H)                ToR(h) -> host h       (access down)
    [2H,             2H + T*S)          ToR t -> spine s       (uplink,   t*S+s)
    [2H + T*S,       2H + 2*T*S)        spine s -> ToR t       (downlink, s*T+t)

* :class:`FatTree` — a 3-tier multi-pod fabric: each pod is a leaf-spine
  block; pod spines connect upward to a core tier (spine s owns the core
  group [s*cpg, (s+1)*cpg)), modelling the paper's multi-pod interconnects
  with independent edge and core oversubscription (§4.1 discussion).

Every path is a fixed-width row of link ids padded with the *null link*
``n_links`` (infinite capacity, owned by no switch).  Candidate paths are
returned as ``[N, P, H]`` tables; the ECMP hash picks ``p % n_paths`` so
fabrics with different fan-outs coexist in one workload.

Hosts are assigned to edge switches contiguously.  An optional
oversubscription factor scales fabric capacity down relative to access
links, modeling the paper's 1:2-1:8 multi-pod interconnects (§4.1).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

DEFAULT_LINK_BPS = 10e9 / 8.0  # 10 Gbps in bytes/s (paper §4.1)

# switch levels (Symphony deployment tiers)
LEVEL_TOR = 1      # edge / ToR switches
LEVEL_SPINE = 2    # aggregation / pod-spine switches
LEVEL_CORE = 3     # core switches


@dataclass(frozen=True)
class Topology:
    """Base link-table topology.

    ``link_switch[l]`` is the id of the switch transmitting on link ``l``
    (-1 when the transmitter is a host NIC); ``switch_level[s]`` is that
    switch's tier (LEVEL_TOR/SPINE/CORE).  Subclasses implement
    :meth:`candidate_paths`.
    """

    n_hosts: int
    link_cap: np.ndarray          # [L] bytes/s
    symphony_mask: np.ndarray     # [L] bool — ports running Symphony (ToR egress)
    link_switch: np.ndarray       # [L] egress switch id, -1 = host NIC
    switch_level: np.ndarray      # [n_switches] LEVEL_* per switch

    @property
    def n_links(self) -> int:
        return int(self.link_cap.shape[0])

    @property
    def n_switches(self) -> int:
        return int(self.switch_level.shape[0])

    @property
    def max_hops(self) -> int:
        """Width H of candidate-path rows."""
        raise NotImplementedError

    def candidate_paths(self, src: np.ndarray, dst: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray]:
        """ECMP candidate paths for each (src, dst) host pair.

        Returns ``(paths [N, P, H] int64, n_paths [N] int64)`` where rows
        ``>= n_paths[i]`` of ``paths[i]`` are unused padding and every hop
        slot that a path does not need holds the null link ``n_links``.
        """
        raise NotImplementedError


@dataclass(frozen=True)
class LeafSpine(Topology):
    n_tors: int = 0
    n_spines: int = 0

    @property
    def hosts_per_tor(self) -> int:
        return self.n_hosts // self.n_tors

    @property
    def max_hops(self) -> int:
        return 4

    # ---- link index helpers (host/tor/spine ids -> link id) ----
    def acc_up(self, host: np.ndarray) -> np.ndarray:
        return np.asarray(host)

    def acc_down(self, host: np.ndarray) -> np.ndarray:
        return self.n_hosts + np.asarray(host)

    def uplink(self, tor: np.ndarray, spine: np.ndarray) -> np.ndarray:
        return 2 * self.n_hosts + np.asarray(tor) * self.n_spines + np.asarray(spine)

    def downlink(self, spine: np.ndarray, tor: np.ndarray) -> np.ndarray:
        return 2 * self.n_hosts + self.n_tors * self.n_spines \
            + np.asarray(spine) * self.n_tors + np.asarray(tor)

    def tor_of(self, host: np.ndarray) -> np.ndarray:
        return np.asarray(host) // self.hosts_per_tor

    def candidate_paths(self, src, dst):
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        N, P, H = src.shape[0], self.n_spines, self.max_hops
        null = self.n_links
        paths = np.full((N, P, H), null, np.int64)
        st, dt = self.tor_of(src), self.tor_of(dst)
        paths[:, :, 0] = self.acc_up(src)[:, None]
        paths[:, :, 3] = self.acc_down(dst)[:, None]
        inter = st != dt
        sp = np.arange(P, dtype=np.int64)
        paths[inter, :, 1] = self.uplink(st[inter, None], sp[None, :])
        paths[inter, :, 2] = self.downlink(sp[None, :], dt[inter, None])
        n_paths = np.where(inter, P, 1).astype(np.int64)
        return paths, n_paths


@dataclass(frozen=True)
class FatTree(Topology):
    """3-tier multi-pod fabric; see the module docstring for link layout:

      [0,      H)            host -> ToR               (acc up)
      [H,      2H)           ToR  -> host              (acc down)
      [2H,     +T*S)         ToR t -> local spine s    (t*S + s)
      [..,     +T*S)         spine (p,s) -> local ToR  ((p*S+s)*Tp + tl)
      [..,     +P*S*cpg)     spine (p,s) -> core       ((p*S+s)*cpg + j)
      [..,     +C*P)         core c -> pod p's spine   (c*P + p)

    with T total ToRs, Tp ToRs/pod, S spines/pod, P pods, C cores and
    cpg = C // S cores per spine group.  Core c attaches to spine c // cpg
    in every pod, so an inter-pod path is fully determined by its core.
    """

    n_pods: int = 0
    tors_per_pod: int = 0
    spines_per_pod: int = 0
    n_cores: int = 0

    @property
    def n_tors(self) -> int:
        return self.n_pods * self.tors_per_pod

    @property
    def hosts_per_tor(self) -> int:
        return self.n_hosts // self.n_tors

    @property
    def cores_per_spine(self) -> int:
        return self.n_cores // self.spines_per_pod

    @property
    def max_hops(self) -> int:
        return 6

    # ---- link index helpers ----
    def acc_up(self, host):
        return np.asarray(host)

    def acc_down(self, host):
        return self.n_hosts + np.asarray(host)

    def tor_of(self, host):
        return np.asarray(host) // self.hosts_per_tor

    def pod_of_tor(self, tor):
        return np.asarray(tor) // self.tors_per_pod

    def uplink(self, tor, spine):
        """ToR t -> spine `spine` (pod-local index) of t's pod."""
        return 2 * self.n_hosts + np.asarray(tor) * self.spines_per_pod \
            + np.asarray(spine)

    def downlink(self, pod, spine, tor):
        """Spine (pod, local s) -> ToR `tor` (global id, must be in pod)."""
        base = 2 * self.n_hosts + self.n_tors * self.spines_per_pod
        tl = np.asarray(tor) % self.tors_per_pod
        return base + (np.asarray(pod) * self.spines_per_pod
                       + np.asarray(spine)) * self.tors_per_pod + tl

    def spine_up(self, pod, spine, core):
        """Spine (pod, local s) -> core (global id, in s's core group)."""
        base = 2 * self.n_hosts + 2 * self.n_tors * self.spines_per_pod
        j = np.asarray(core) % self.cores_per_spine
        return base + (np.asarray(pod) * self.spines_per_pod
                       + np.asarray(spine)) * self.cores_per_spine + j

    def core_down(self, core, pod):
        """Core c -> spine c // cpg of pod `pod`."""
        base = 2 * self.n_hosts + 2 * self.n_tors * self.spines_per_pod \
            + self.n_pods * self.spines_per_pod * self.cores_per_spine
        return base + np.asarray(core) * self.n_pods + np.asarray(pod)

    def candidate_paths(self, src, dst):
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        S, C = self.spines_per_pod, self.n_cores
        N, P, H = src.shape[0], max(S, C), self.max_hops
        null = self.n_links
        paths = np.full((N, P, H), null, np.int64)
        st, dt = self.tor_of(src), self.tor_of(dst)
        sp, dp = self.pod_of_tor(st), self.pod_of_tor(dt)
        paths[:, :, 0] = self.acc_up(src)[:, None]
        paths[:, :, H - 1] = self.acc_down(dst)[:, None]
        # intra-pod, inter-ToR: one candidate per pod spine
        ip = np.nonzero((sp == dp) & (st != dt))[0][:, None]
        s_idx = np.arange(S, dtype=np.int64)[None, :]
        paths[ip, s_idx, 1] = self.uplink(st[ip], s_idx)
        paths[ip, s_idx, 2] = self.downlink(sp[ip], s_idx, dt[ip])
        # inter-pod: one candidate per core; spine = core // cpg on both sides
        xp = sp != dp
        rows = np.nonzero(xp)[0][:, None]
        c_idx = np.arange(C, dtype=np.int64)[None, :]
        cs = c_idx // self.cores_per_spine
        paths[rows, c_idx, 1] = self.uplink(st[rows], cs)
        paths[rows, c_idx, 2] = self.spine_up(sp[rows], cs, c_idx)
        paths[rows, c_idx, 3] = self.core_down(c_idx, dp[rows])
        paths[rows, c_idx, 4] = self.downlink(dp[rows], cs, dt[rows])
        n_paths = np.where(xp, C,
                           np.where(st != dt, S, 1)).astype(np.int64)
        return paths, n_paths


def make_leaf_spine(
    n_hosts: int = 32,
    n_tors: int = 4,
    n_spines: int = 4,
    link_bps: float = DEFAULT_LINK_BPS,
    oversubscription: float = 1.0,
) -> LeafSpine:
    """Build the paper's default 4 ToR x 4 spine, 32-host fabric (Table 1).

    ``oversubscription`` > 1 shrinks fabric (ToR<->spine) capacity: a value of
    4 models a 1:4 oversubscribed tier.
    """
    if n_hosts % n_tors:
        raise ValueError(f"hosts ({n_hosts}) must divide evenly over ToRs ({n_tors})")
    n_fabric = 2 * n_tors * n_spines
    L = 2 * n_hosts + n_fabric
    cap = np.full(L, link_bps, np.float64)
    cap[2 * n_hosts:] = link_bps * (n_hosts / n_tors) / n_spines / oversubscription \
        if oversubscription != 1.0 else link_bps
    # Symphony runs on ToR egress ports: uplinks (ToR->spine) and access-down
    # (ToR->host) — §5 "Practical deployment": ToR-only is sufficient.
    mask = np.zeros(L, bool)
    mask[n_hosts:2 * n_hosts] = True            # ToR -> host
    mask[2 * n_hosts: 2 * n_hosts + n_tors * n_spines] = True  # ToR -> spine
    # egress-switch ownership: switches are ToRs [0, T) then spines [T, T+S)
    hpt = n_hosts // n_tors
    sw = np.full(L, -1, np.int32)
    sw[n_hosts:2 * n_hosts] = np.arange(n_hosts) // hpt          # ToR -> host
    sw[2 * n_hosts:2 * n_hosts + n_tors * n_spines] = \
        np.repeat(np.arange(n_tors), n_spines)                   # ToR -> spine
    sw[2 * n_hosts + n_tors * n_spines:] = \
        n_tors + np.repeat(np.arange(n_spines), n_tors)          # spine -> ToR
    level = np.concatenate([np.full(n_tors, LEVEL_TOR, np.int32),
                            np.full(n_spines, LEVEL_SPINE, np.int32)])
    return LeafSpine(n_hosts=n_hosts, n_tors=n_tors, n_spines=n_spines,
                     link_cap=cap, symphony_mask=mask, link_switch=sw,
                     switch_level=level)


def make_fat_tree(
    n_pods: int = 2,
    tors_per_pod: int = 2,
    spines_per_pod: int = 2,
    hosts_per_tor: int = 4,
    n_cores: int | None = None,
    link_bps: float = DEFAULT_LINK_BPS,
    oversubscription: float = 1.0,
    core_oversubscription: float = 1.0,
) -> FatTree:
    """Build a 3-tier multi-pod fat-tree.

    ``oversubscription`` scales the edge tier (ToR<->spine) and
    ``core_oversubscription`` the core tier (spine<->core) relative to a
    non-blocking fabric, matching the paper's 1:2-1:8 multi-pod setups.
    """
    n_cores = spines_per_pod if n_cores is None else n_cores
    if n_cores % spines_per_pod:
        raise ValueError(f"cores ({n_cores}) must divide evenly over "
                         f"pod spines ({spines_per_pod})")
    T = n_pods * tors_per_pod
    S, C, P = spines_per_pod, n_cores, n_pods
    H = T * hosts_per_tor
    cpg = C // S
    n_edge = T * S                 # per direction
    n_core_up = P * S * cpg        # spine -> core
    n_core_down = C * P            # core -> pod
    L = 2 * H + 2 * n_edge + n_core_up + n_core_down
    cap = np.full(L, link_bps, np.float64)
    edge_cap = link_bps * hosts_per_tor / S / oversubscription
    cap[2 * H:2 * H + 2 * n_edge] = edge_cap
    core_cap = link_bps * (tors_per_pod * hosts_per_tor) / C \
        / core_oversubscription
    cap[2 * H + 2 * n_edge:] = core_cap
    # Symphony default mask: ToR egress (acc-down + uplinks), §5 deployment.
    mask = np.zeros(L, bool)
    mask[H:2 * H + n_edge] = True
    # switches: ToRs [0, T), spines [T, T+P*S), cores [T+P*S, T+P*S+C)
    sw = np.full(L, -1, np.int32)
    sw[H:2 * H] = np.arange(H) // hosts_per_tor                  # ToR -> host
    sw[2 * H:2 * H + n_edge] = np.repeat(np.arange(T), S)        # ToR -> spine
    sw[2 * H + n_edge:2 * H + 2 * n_edge] = \
        T + np.repeat(np.arange(P * S), tors_per_pod)            # spine -> ToR
    sw[2 * H + 2 * n_edge:2 * H + 2 * n_edge + n_core_up] = \
        T + np.repeat(np.arange(P * S), cpg)                     # spine -> core
    sw[2 * H + 2 * n_edge + n_core_up:] = \
        T + P * S + np.repeat(np.arange(C), P)                   # core -> pod
    level = np.concatenate([np.full(T, LEVEL_TOR, np.int32),
                            np.full(P * S, LEVEL_SPINE, np.int32),
                            np.full(C, LEVEL_CORE, np.int32)])
    return FatTree(n_hosts=H, link_cap=cap, symphony_mask=mask,
                   link_switch=sw, switch_level=level,
                   n_pods=P, tors_per_pod=tors_per_pod, spines_per_pod=S,
                   n_cores=C)


def scale_for_hosts(n_hosts: int, link_bps: float = DEFAULT_LINK_BPS,
                    oversubscription: float = 1.0) -> LeafSpine:
    """Paper-style scaling: 8 hosts per ToR; spines sized to keep the fabric
    non-blocking at oversubscription=1 (S = hosts_per_tor)."""
    n_tors = max(2, n_hosts // 8)
    n_spines = max(2, min(8, n_hosts // n_tors))
    return make_leaf_spine(n_hosts, n_tors, n_spines, link_bps, oversubscription)
