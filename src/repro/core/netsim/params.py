"""Simulator configuration, split along the jit boundary.

The config layer has three faces:

* :class:`SimStructure` — the *static* part: everything that determines
  array shapes or trace-time control flow (tick count, window size,
  sampling period, share-policy name, Symphony deployment tier, routing
  mode).  Hashable, passed to ``jax.jit`` via ``static_argnames``;
  changing any field recompiles.
* :class:`RuntimeKnobs` — the *traced* part: every numeric control knob
  (RED thresholds, DCQCN constants, Symphony gains, on/off gates) as a
  pytree of f32/i32 scalar leaves.  Changing values never recompiles,
  and a stacked ``RuntimeKnobs`` (leading axis ``K``) vmaps a whole
  parameter grid through one compilation of the engine.
* :class:`SimParams` — the backwards-compatible facade: the flat
  NamedTuple every existing caller builds.  :meth:`SimParams.split`
  produces ``(structure, knobs)``; :func:`merge_params` reassembles an
  attribute-compatible view (:class:`EngineParams`) for the stage
  kernels, which read static fields as Python scalars and knob fields
  as (possibly batched) arrays.

Boolean knobs (``sym_on``, ``pq_on``) become 0/1 gates: the engine
always traces both sides and selects, so a single compiled program
serves baseline, PQ, and Symphony points of a grid.

This module also owns the kernel *tiling plan* (:func:`plan_tiling`) and
the trace-time route-table packer (:class:`PackedTables` /
:func:`pack_route_tables`): per-instance dense copies of every table the
tick kernel used to gather from (`routes[inst_flow]`, the ECMP candidate
slab, `chunk_sched[inst_job]`), so the tiled Pallas kernel can stream
them block-by-block and stay gather-free (Mosaic has no vector-gather
lowering).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from ..symphony import SymphonyParams


class SimParams(NamedTuple):
    """Flat simulator config (facade; see module docstring for the split)."""
    dt: float = 10e-6
    n_ticks: int = 20_000
    window: int = 48               # max concurrent steps per slot (W)
    mtu: float = 1000.0            # bytes per "packet" (psn unit)
    record_every: int = 20         # metric sampling period (ticks)
    # RED / ECN (bytes)
    red_kmin: float = 50e3
    red_kmax: float = 100e3
    red_pmax: float = 0.2
    # DCQCN-style rate control
    cc_epoch_ticks: int = 5        # 50 us control epoch
    cc_g: float = 1.0 / 16.0
    cc_rai: float = 5e6            # additive increase (bytes/s) = 40 Mb/s
    cc_rhai: float = 25e6          # hyper increase
    cc_fr_stages: int = 5
    cc_min_rate: float = 1.25e5    # 1 Mb/s floor (paper §5 "soft limit")
    # Symphony
    sym_on: bool = False
    sym: SymphonyParams = SymphonyParams()
    sym_win_ticks: int = 10        # T_win = 100 us
    sym_start_tick: int = 0        # late-start experiments (Fig. 4)
    deploy: str = "tor"            # Symphony tier: "tor" | "all" | "spine"
    # Alternatives / knobs
    pq_on: bool = False            # strict-priority for lagging flows (Fig. 5)
    share_policy: str = "proportional"  # proportional | pq | wfq | drr
    per_step_ecmp: bool = True     # re-hash the 5-tuple every step (§4.7: the
                                   # step index lives in the UDP sport, so each
                                   # step is a distinct flow to ECMP)
    backend: str = "xla"           # tick hot-path backend: "xla" staged ops |
                                   # "pallas" fused kernel (kernels/netsim_tick)
    segsum: str = "scatter"        # kernel segment reductions: "scatter"
                                   # (.at[].add, bitwise reference) | "onehot"
                                   # (dense contractions, the Mosaic shape)
    blk: int | None = None         # instance-axis tile for the onehot kernel
                                   # (None = whole [FW] in one block)
    tick_window: int = 1           # ticks fused per kernel invocation
                                   # (pallas backend; amortizes state HBM
                                   # round trips 1/tick_window)

    def structure(self) -> "SimStructure":
        return SimStructure(
            dt=self.dt, n_ticks=self.n_ticks, window=self.window,
            mtu=self.mtu, record_every=self.record_every,
            share_policy=self.share_policy, deploy=self.deploy,
            per_step_ecmp=self.per_step_ecmp, backend=self.backend,
            segsum=self.segsum, blk=self.blk, tick_window=self.tick_window)

    def knobs(self) -> "RuntimeKnobs":
        f32 = lambda v: jnp.asarray(v, jnp.float32)
        i32 = lambda v: jnp.asarray(v, jnp.int32)
        return RuntimeKnobs(
            red_kmin=f32(self.red_kmin), red_kmax=f32(self.red_kmax),
            red_pmax=f32(self.red_pmax),
            cc_epoch_ticks=i32(self.cc_epoch_ticks), cc_g=f32(self.cc_g),
            cc_rai=f32(self.cc_rai), cc_rhai=f32(self.cc_rhai),
            cc_fr_stages=i32(self.cc_fr_stages),
            cc_min_rate=f32(self.cc_min_rate),
            sym_on=i32(self.sym_on),
            sym=SymphonyParams(*(f32(v) for v in self.sym)),
            sym_win_ticks=i32(self.sym_win_ticks),
            sym_start_tick=i32(self.sym_start_tick),
            pq_on=i32(self.pq_on))

    def split(self) -> tuple["SimStructure", "RuntimeKnobs"]:
        return self.structure(), self.knobs()


class SimStructure(NamedTuple):
    """Shape/compile-time structure: hashable, a jit static argument."""
    dt: float = 10e-6
    n_ticks: int = 20_000
    window: int = 48
    mtu: float = 1000.0
    record_every: int = 20
    share_policy: str = "proportional"
    deploy: str = "tor"
    per_step_ecmp: bool = True
    backend: str = "xla"
    segsum: str = "scatter"
    blk: int | None = None
    tick_window: int = 1


class RuntimeKnobs(NamedTuple):
    """Device-traced control knobs: a pytree of f32/i32 scalar leaves.

    Stack along a leading axis (:func:`stack_knobs`) to form a grid that
    ``simulate_grid`` vmaps through a single compilation.
    """
    red_kmin: jax.Array
    red_kmax: jax.Array
    red_pmax: jax.Array
    cc_epoch_ticks: jax.Array
    cc_g: jax.Array
    cc_rai: jax.Array
    cc_rhai: jax.Array
    cc_fr_stages: jax.Array
    cc_min_rate: jax.Array
    sym_on: jax.Array            # 0/1 gate (traced; no recompile to toggle)
    sym: SymphonyParams          # five f32 leaves (k, tau, warmup, sample, amax)
    sym_win_ticks: jax.Array
    sym_start_tick: jax.Array
    pq_on: jax.Array             # 0/1 gate: strict-priority override


class SimState(NamedTuple):
    """The public checkpoint/resume carry of a simulation in flight.

    A pure pytree of device arrays: the tick cursor plus the *full* engine
    scan carry (:class:`~repro.core.netsim.stages.EngineState` — slot,
    instance, link, Symphony, and job state, including the CC PRNG key).
    Produced by ``simulator.init_state``, advanced by
    ``simulator.run_window`` / ``control.SimController.step``, and
    serializable with ``jax.device_get`` — resuming from a checkpointed
    ``SimState`` is bit-for-bit identical to having never paused.

    ``engine`` is typed ``Any`` only to avoid a circular import with
    :mod:`.stages`; it is always an ``EngineState``.
    """
    tick: jax.Array      # i32 scalar: the next tick to execute
    engine: Any          # stages.EngineState — the full tick carry


class EngineParams(NamedTuple):
    """Merged trace-time view handed to the stage kernels.

    Field names match :class:`SimParams`, so stages written against the
    flat config keep working: static fields are Python scalars, knob
    fields are arrays (scalars, or batched under vmap).  Not a jit
    argument — it is assembled inside ``simulate_core`` and closed over
    by the scanned tick function.
    """
    dt: float
    n_ticks: int
    window: int
    mtu: float
    record_every: int
    share_policy: str
    deploy: str
    per_step_ecmp: bool
    backend: str
    segsum: str
    blk: int | None
    tick_window: int
    red_kmin: jax.Array
    red_kmax: jax.Array
    red_pmax: jax.Array
    cc_epoch_ticks: jax.Array
    cc_g: jax.Array
    cc_rai: jax.Array
    cc_rhai: jax.Array
    cc_fr_stages: jax.Array
    cc_min_rate: jax.Array
    sym_on: jax.Array
    sym: SymphonyParams
    sym_win_ticks: jax.Array
    sym_start_tick: jax.Array
    pq_on: jax.Array


def merge_params(struct: SimStructure, knobs: RuntimeKnobs) -> EngineParams:
    return EngineParams(
        dt=struct.dt, n_ticks=struct.n_ticks, window=struct.window,
        mtu=struct.mtu, record_every=struct.record_every,
        share_policy=struct.share_policy, deploy=struct.deploy,
        per_step_ecmp=struct.per_step_ecmp, backend=struct.backend,
        segsum=struct.segsum, blk=struct.blk, tick_window=struct.tick_window,
        **knobs._asdict())


def stack_knobs(knobs: Sequence[RuntimeKnobs]) -> RuntimeKnobs:
    """Stack scalar knob pytrees into one grid pytree with leading axis K."""
    if not knobs:
        raise ValueError("empty knob grid")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *knobs)


def grid_from_params(cfgs: Sequence[SimParams]
                     ) -> tuple[SimStructure, RuntimeKnobs]:
    """Split a list of SimParams into (shared structure, stacked knobs).

    All cfgs must agree on every structural field — a grid sweeps knob
    values through one compiled program, it cannot change shapes.
    """
    if not cfgs:
        raise ValueError("empty parameter grid")
    structs = {cfg.structure() for cfg in cfgs}
    if len(structs) > 1:
        a, b, *_ = structs
        diff = [f for f, x, y in zip(a._fields, a, b) if x != y]
        raise ValueError(
            f"grid points differ in static structure (fields {diff}); "
            "sweep only RuntimeKnobs fields, or run separate grids")
    return cfgs[0].structure(), stack_knobs([cfg.knobs() for cfg in cfgs])


# ------------------------------------------------ kernel tiling + tables
class PackedTables(NamedTuple):
    """Per-instance dense route/chunk/ECMP tables for the tick kernel.

    Every array leads with the flat ``[FW]`` instance axis, so the tiled
    grid kernel can BlockSpec-stream them in ``blk``-row slabs (edge-
    padded like the other per-instance operands) and every former
    ``table[index]`` gather becomes a block-local row read or an
    iota-select.  Packed once per trace by :func:`pack_route_tables`
    (``jnp.repeat`` over the window axis — broadcast + reshape, itself
    gather-free), carried on ``EngineCtx.tables``.
    """
    routes: jax.Array     # [FW, H]    static per-instance route links
    route_dom: jax.Array  # [FW, H]    Symphony domain of each static hop
    cand: jax.Array       # [FW, P, H] ECMP candidate paths per instance
    cand_dom: jax.Array   # [FW, P, H] domains of the candidate hops
    n_paths: jax.Array    # [FW]       valid candidate count per instance
    chunk: jax.Array      # [FW, SEG]  per-instance segment chunk sizes


def pack_route_tables(st, wl, window: int) -> PackedTables:
    """Expand the per-flow/per-job tables to the ``[FW]`` instance axis.

    ``st`` needs ``routes``/``path_table``/``n_paths``/``link_dom``;
    ``wl`` needs ``job``/``chunk_sched`` (duck-typed: `simulator.Static`
    and `stages.WLArrays`).  The window expansion is ``jnp.repeat(x, W,
    axis=0)`` — row ``f*W + w`` holds flow ``f``'s table, matching the
    ``inst_flow``/``inst_job`` layout of `stages.make_ctx`.
    """
    W = int(window)

    def per_inst(x):
        return jnp.repeat(x, W, axis=0)

    return PackedTables(
        routes=per_inst(st.routes),
        route_dom=per_inst(st.link_dom[st.routes]),
        cand=per_inst(st.path_table),
        cand_dom=per_inst(st.link_dom[st.path_table]),
        n_paths=per_inst(st.n_paths),
        chunk=per_inst(wl.chunk_sched[wl.job]),
    )


def plan_tiling(FW: int, blk: int | None, segsum: str,
                tick_window: int) -> int | None:
    """Validate and normalize the kernel tiling plan for an ``[FW]``
    instance axis: returns the effective ``blk`` (``None`` = untiled).

    * ``blk >= FW`` normalizes to untiled (one whole-array block).
    * ``blk`` tiling requires the dense ``segsum="onehot"`` reductions —
      the scatter variant cannot accumulate per-block partials without
      the vector scatters the tiling exists to eliminate.
    * ``tick_window > 1`` dispatches through the multi-tick window
      kernel, which keeps the whole ``[FW]`` axis (and the packed route
      tables) VMEM-resident across its in-kernel ``fori_loop`` — so the
      single-tick grid tiling normalizes away and ``blk`` combines
      freely with windowing (the combined ``blk x tick_window`` config
      is golden-tested).
    """
    if blk is None:
        return None
    if blk < 1:
        raise ValueError(f"blk must be >= 1, got {blk}")
    if int(blk) >= FW:
        return None
    if segsum != "onehot":
        raise ValueError(
            f"blk={blk} tiling requires segsum='onehot'; "
            f"got segsum={segsum!r}")
    if tick_window > 1:
        return None
    return int(blk)
