"""Composable per-tick stages of the fluid network-simulation engine.

The simulator's tick is decomposed into small, individually-testable pure
functions over an :class:`EngineCtx` (static per-run arrays + dims) and an
:class:`EngineState` (the `lax.scan` carry).  :func:`engine_tick` composes
them; `simulator.simulate_core` wraps that composition in one scan so the
whole run still jits/vmaps as a single program.

Stage order (one tick):

1. :func:`stage_starts`        — segment barrier + ring dependency gating
2. :func:`instance_view`       — per-instance arrays incl. route selection
                                 (per-step ECMP re-hash over the candidate
                                 path table, any hop count)
3. :func:`stage_share`         — bandwidth sharing: ``proportional`` fluid
                                 max-min approximation, ``pq`` 2-class
                                 strict priority, ``wfq`` weighted fair,
                                 ``drr`` deficit round-robin; the traced
                                 ``pq_on`` gate overrides at runtime
4. :func:`stage_queues`        — queue integration + RED profile
5. :func:`stage_marking`       — RED x Symphony selective marking -> lambda
6. :func:`stage_progress`      — byte progress, completions, finish times
7. :func:`stage_symphony`      — per-(domain, job) state block updates
8. :func:`stage_rate_control`  — DCQCN-style epoch update
9. :func:`stage_segments`      — segment barriers and job finish
10. :func:`stage_metrics`      — sampled observables

The ``cfg`` argument of every stage is attribute-compatible with both the
flat :class:`~repro.core.netsim.params.SimParams` (all-Python legacy view)
and the merged :class:`~repro.core.netsim.params.EngineParams`, whose knob
fields (RED/CC/Symphony constants, ``sym_on``/``pq_on`` gates) are traced
arrays — so the same stage code serves single runs and vmapped knob grids
without retracing per parameter point.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..symphony import marking_probability
from .params import PackedTables, pack_route_tables

# Wire-step encoding: global segment index * WIRE_SEG + step-within-segment.
# Monotone across segments; comparable across flows inside a segment.
WIRE_SEG = 4096
I32MAX = np.iinfo(np.int32).max
# Python int, not jnp.int32: promotes weakly to int32 in every use
# (identical values), and keeps stage code callable inside Pallas kernel
# bodies, which cannot capture device-array constants (the multi-tick
# window kernel replays the stages per tick).
BIG = 2**30


class WLArrays(NamedTuple):
    src: jax.Array; dst: jax.Array; pred: jax.Array; job: jax.Array
    phase: jax.Array; sps: jax.Array; pass_steps: jax.Array
    total_steps: jax.Array
    n_phases: jax.Array; n_segs: jax.Array; chunk_sched: jax.Array
    gap_ticks: jax.Array; start_ticks: jax.Array
    step_offset: jax.Array; fstart_ticks: jax.Array
    # dependency-triggered arrivals (all [J] i32; trig_job=-1 => fixed start)
    trig_job: jax.Array; trig_seg: jax.Array; trig_delay_ticks: jax.Array


class EngineState(NamedTuple):
    """The scan carry: slot, instance, link, Symphony, and job state."""
    # slot level [F]
    next_step: jax.Array; done_upto: jax.Array; finish: jax.Array
    # instance level [F, W]
    step_of: jax.Array; sent: jax.Array
    rate: jax.Array; target: jax.Array; alpha_cc: jax.Array; stage: jax.Array
    lam: jax.Array                     # accumulated expected marks this epoch
    # link level [L+1]
    q: jax.Array
    # Symphony per (link-domain, job), flattened [(D+1) * J]
    s_stepmin: jax.Array; s_psnwin: jax.Array; s_alpha: jax.Array
    s_cnt: jax.Array; s_cntop: jax.Array
    # job level [J]
    seg_idx: jax.Array; seg_ready: jax.Array; job_finish: jax.Array
    key: jax.Array


@dataclass(frozen=True)
class EngineCtx:
    """Static (trace-time) context: dims, broadcast views, device arrays.

    Not a pytree — it is closed over by the scanned tick function, so all
    integer fields stay Python ints and keep shapes static.
    """
    st: Any                  # Static device arrays (routes, caps, domains, ..)
    wl: WLArrays
    F: int; J: int; W: int; L: int; H: int; D: int
    fidx: jax.Array          # [F]
    nph_f: jax.Array         # [F] phases per pass of each flow's job
    line_rate: jax.Array     # [F] access-link rate
    inst_job: jax.Array      # [FW]
    inst_flow: jax.Array     # [FW]
    sps_i: jax.Array; phase_i: jax.Array; nph_i: jax.Array; off_i: jax.Array
    iroute_static: jax.Array  # [FW, H]
    # per-instance dense route/chunk/ECMP tables (params.PackedTables):
    # the gather-free tiled kernel streams these instead of gathering.
    tables: PackedTables | None = None

    @property
    def FW(self) -> int:
        return self.F * self.W

    def chunk_of(self, job_ids, seg):
        max_seg = int(self.wl.chunk_sched.shape[1])
        return self.wl.chunk_sched[job_ids, jnp.clip(seg, 0, max_seg - 1)]


def make_ctx(st, wl: WLArrays, window: int,
             tables: PackedTables | None = None) -> EngineCtx:
    F = int(wl.src.shape[0])
    J = int(wl.n_phases.shape[0])
    W = window
    L = int(st.cap.shape[0]) - 1
    H = int(st.routes.shape[-1])
    D = int(st.dom_pad.shape[-1]) - 1   # null domain id (static)
    FW = F * W
    nph_f = wl.n_phases[wl.job]
    fidx = jnp.arange(F)
    return EngineCtx(
        st=st, wl=wl, F=F, J=J, W=W, L=L, H=H, D=D,
        fidx=fidx, nph_f=nph_f,
        line_rate=st.cap[st.routes[:, 0]],
        inst_job=jnp.broadcast_to(wl.job[:, None], (F, W)).reshape(FW),
        inst_flow=jnp.broadcast_to(fidx[:, None], (F, W)).reshape(FW),
        sps_i=jnp.broadcast_to(wl.sps[:, None], (F, W)).reshape(FW),
        phase_i=jnp.broadcast_to(wl.phase[:, None], (F, W)).reshape(FW),
        nph_i=jnp.broadcast_to(nph_f[:, None], (F, W)).reshape(FW),
        off_i=jnp.broadcast_to(wl.step_offset[:, None], (F, W)).reshape(FW),
        iroute_static=jnp.broadcast_to(
            st.routes[:, None, :], (F, W, st.routes.shape[-1])
        ).reshape(FW, st.routes.shape[-1]),
        tables=pack_route_tables(st, wl, W) if tables is None else tables,
    )


def init_state(ctx: EngineCtx, key: jax.Array) -> EngineState:
    F, W, J, L, D = ctx.F, ctx.W, ctx.J, ctx.L, ctx.D
    DJ = (D + 1) * J
    wl = ctx.wl
    return EngineState(
        next_step=jnp.zeros(F, jnp.int32),
        done_upto=jnp.zeros(F, jnp.int32),
        finish=jnp.full(F, I32MAX, jnp.int32),
        step_of=jnp.full((F, W), -1, jnp.int32),
        sent=jnp.zeros((F, W), jnp.float32),
        rate=jnp.zeros((F, W), jnp.float32) + ctx.line_rate[:, None],
        target=jnp.zeros((F, W), jnp.float32) + ctx.line_rate[:, None],
        alpha_cc=jnp.ones((F, W), jnp.float32),
        stage=jnp.zeros((F, W), jnp.int32),
        lam=jnp.zeros((F, W), jnp.float32),
        q=jnp.zeros(L + 1, jnp.float32),
        s_stepmin=jnp.zeros(DJ, jnp.int32),
        s_psnwin=jnp.zeros(DJ, jnp.float32),
        s_alpha=jnp.ones(DJ, jnp.float32),
        s_cnt=jnp.zeros(DJ, jnp.float32),
        s_cntop=jnp.zeros(DJ, jnp.float32),
        seg_idx=jnp.zeros(J, jnp.int32),
        # Triggered jobs hold at the I32MAX sentinel until stage_segments
        # releases them (dependency satisfied); fixed-start jobs keep the
        # legacy start+gap release tick.
        seg_ready=jnp.where(wl.trig_job >= 0, I32MAX,
                            wl.start_ticks + wl.gap_ticks),
        job_finish=jnp.full(J, I32MAX, jnp.int32),
        key=key,
    )


def seg_global(c, sps, phase, n_phases):
    """Global segment index of local step c for a flow slot."""
    return (c // sps) * n_phases + phase


def wire_step(c, sps, phase, n_phases):
    """Monotone wire-step encoding (§3.2) of local step c."""
    return seg_global(c, sps, phase, n_phases) * WIRE_SEG + (c % sps)


# ------------------------------------------------------------- 1. starts
class Starts(NamedTuple):
    next_step: jax.Array
    step_of: jax.Array; sent: jax.Array
    rate: jax.Array; target: jax.Array; alpha_cc: jax.Array
    stage: jax.Array; lam: jax.Array
    can: jax.Array


def stage_starts(ctx: EngineCtx, state: EngineState, tick) -> Starts:
    """Gate new step-sends on segment barrier + ring data dependency + slot
    availability, and initialize the window slots of the started steps."""
    wl, fidx, W = ctx.wl, ctx.fidx, ctx.W
    s_next = state.next_step
    seg_of_next = seg_global(s_next, wl.sps, wl.phase, ctx.nph_f)
    seg_ok = (seg_of_next == state.seg_idx[wl.job]) & \
             (tick >= state.seg_ready[wl.job])
    # Ring data dependency. Within a collective, send(s) needs only
    # recv(s-1) == predecessor's *step s-1* send completed (steps carry
    # independent chunks, so no contiguity requirement).  At a collective
    # boundary (s % pass_steps == 0) the node needs its previous
    # collective complete: all own sends and all receives done.
    boundary = (s_next % wl.pass_steps) == 0
    w_prev = (s_next - 1) % W
    ps_prev = state.step_of[wl.pred, w_prev]
    prev_chunk = ctx.chunk_of(
        wl.job, seg_global(s_next - 1, wl.sps, wl.phase, ctx.nph_f))
    pred_prev_done = (state.done_upto[wl.pred] >= s_next) | \
        (ps_prev > s_next - 1) | \
        ((ps_prev == s_next - 1) &
         (state.sent[wl.pred, w_prev] >= prev_chunk))
    pass_done = (state.done_upto >= s_next) & \
        (state.done_upto[wl.pred] >= s_next)
    ring_ok = jnp.where(boundary, (s_next == 0) | pass_done, pred_prev_done)
    ring_ok &= tick >= wl.fstart_ticks
    w_next = s_next % W
    slot = state.step_of[fidx, w_next]
    slot_free = (slot < 0) | (slot < state.done_upto)
    can = (s_next < wl.total_steps) & seg_ok & ring_ok & slot_free

    def upd(arr, val):
        return arr.at[fidx, w_next].set(
            jnp.where(can, val, arr[fidx, w_next]))

    return Starts(
        next_step=jnp.where(can, s_next + 1, s_next),
        step_of=upd(state.step_of, s_next),
        sent=upd(state.sent, 0.0),
        rate=upd(state.rate, ctx.line_rate),
        target=upd(state.target, ctx.line_rate),
        alpha_cc=upd(state.alpha_cc, 1.0),
        stage=upd(state.stage, 0),
        lam=upd(state.lam, 0.0),
        can=can,
    )


# ------------------------------------------------------- 2. instance view
def per_hop(x: jax.Array, H: int) -> jax.Array:
    """Expand a per-instance [FW] array to one entry per (instance, hop)
    [FW*H], aligned with ``InstView.flat_links`` / ``.djf``."""
    return jnp.repeat(x, H)


def link_scatter_sum(flat_links: jax.Array, vals: jax.Array, H: int,
                     n_rows: int) -> jax.Array:
    """Scatter-add per-instance values onto their path links: the one
    segment-sum every share policy (and the fused kernel) is built on."""
    return jnp.zeros(n_rows).at[flat_links].add(per_hop(vals, H))


class InstView(NamedTuple):
    """Flattened [FW] per-instance arrays for this tick.

    The per-hop expansion (``jnp.repeat(..., H)``) and the flat-link
    scatter setup are precomputed once here and consumed through
    :meth:`per_hop` / :meth:`link_sum` / :meth:`path_min`, so every share
    policy — and the fused ``netsim_tick`` kernel — shares one index set
    instead of rebuilding it per policy.
    """
    istep: jax.Array; isent: jax.Array; irate: jax.Array
    iseg: jax.Array; ichunk: jax.Array; iwire: jax.Array; ipsn: jax.Array
    occupied: jax.Array; retired: jax.Array; complete: jax.Array
    active: jax.Array
    iroute: jax.Array        # [FW, H] link ids
    flat_links: jax.Array    # [FW*H]
    idom: jax.Array          # [FW, H] Symphony domain per hop
    dj: jax.Array            # [FW, H] (domain, job) row ids
    djf: jax.Array           # [FW*H]

    @property
    def H(self) -> int:
        return int(self.iroute.shape[-1])

    def per_hop(self, x: jax.Array) -> jax.Array:
        """[FW] -> [FW*H], aligned with ``flat_links``."""
        return per_hop(x, self.H)

    def link_sum(self, ctx: "EngineCtx", vals: jax.Array) -> jax.Array:
        """Scatter-add per-instance ``vals`` onto the [L+1] link axis."""
        return link_scatter_sum(self.flat_links, vals, self.H, ctx.L + 1)

    def path_min(self, per_link: jax.Array) -> jax.Array:
        """Worst per-hop value along each instance's path: [L+1] -> [FW]."""
        return per_link[self.iroute].min(axis=1)


def select_routes(ctx: EngineCtx, istep, per_step_ecmp: bool) -> jax.Array:
    """Per-instance routes.  With per-step ECMP the step index is part of the
    5-tuple (paper §4.7: it lives in the UDP sport), so each step re-rolls
    its hash over the flow's candidate-path table; otherwise routes are the
    static per-flow paths."""
    if not per_step_ecmp:
        return ctx.iroute_static
    st = ctx.st
    h = (ctx.inst_flow.astype(jnp.uint32) * jnp.uint32(2654435761)
         + jnp.maximum(istep, 0).astype(jnp.uint32) * jnp.uint32(40503)
         + (st.seed.astype(jnp.uint32) + 1) * jnp.uint32(2246822519))
    h = (h ^ (h >> 13)) * jnp.uint32(2654435761)
    h = h ^ (h >> 16)
    n_paths = st.n_paths[ctx.inst_flow].astype(jnp.uint32)
    choice = (h % n_paths).astype(jnp.int32)
    return st.path_table[ctx.inst_flow, choice]


def instance_view(ctx: EngineCtx, starts: Starts, state: EngineState,
                  mtu: float, per_step_ecmp: bool,
                  iroute: jax.Array | None = None) -> InstView:
    """Assemble the per-instance view.  ``iroute`` may be precomputed (the
    fused kernel selects routes on-chip and hands them back) — otherwise
    it is derived here via :func:`select_routes`."""
    st, J = ctx.st, ctx.J
    istep = starts.step_of.reshape(ctx.FW)
    isent = starts.sent.reshape(ctx.FW)
    irate = starts.rate.reshape(ctx.FW)
    iseg = seg_global(istep, ctx.sps_i, ctx.phase_i, ctx.nph_i)
    ichunk = ctx.chunk_of(ctx.inst_job, iseg)
    iwire = wire_step(istep, ctx.sps_i, ctx.phase_i, ctx.nph_i) + ctx.off_i
    occupied = istep >= 0
    retired = occupied & (istep < state.done_upto[ctx.inst_flow])
    complete = occupied & (isent >= ichunk)
    active = occupied & ~complete & ~retired
    if iroute is None:
        iroute = select_routes(ctx, istep, per_step_ecmp)
    idom = st.link_dom[iroute]
    dj = idom * J + ctx.inst_job[:, None]
    return InstView(
        istep=istep, isent=isent, irate=irate, iseg=iseg, ichunk=ichunk,
        iwire=iwire, ipsn=isent / mtu,
        occupied=occupied, retired=retired, complete=complete, active=active,
        iroute=iroute, flat_links=iroute.reshape(-1),
        idom=idom, dj=dj, djf=dj.reshape(-1),
    )


# ---------------------------------------------------- 3. bandwidth sharing
def background_load(ctx: EngineCtx, tick) -> jax.Array:
    st = ctx.st
    bg_on = (tick % st.bg_period_ticks).astype(jnp.float32) < \
        st.bg_duty * st.bg_period_ticks.astype(jnp.float32)
    return st.bg_base + jnp.where(bg_on, st.bg_amp, 0.0)


class ShareResult(NamedTuple):
    eff: jax.Array       # [FW] delivered bytes/s per instance
    offered: jax.Array   # [L+1] offered load per link (drives the queues)


def share_proportional(ctx: EngineCtx, cfg, inst: InstView, tick
                       ) -> ShareResult:
    """Fluid max-min approximation: every link scales its offered load by
    cap/offered; an instance gets the worst scale along its path."""
    st = ctx.st
    w_rate = jnp.where(inst.active, inst.irate, 0.0)
    bg = background_load(ctx, tick)
    offered = inst.link_sum(ctx, w_rate) + bg
    s_l = jnp.minimum(1.0, st.cap / jnp.maximum(offered, 1.0))
    return ShareResult(eff=w_rate * inst.path_min(s_l), offered=offered)


def share_pq(ctx: EngineCtx, cfg, inst: InstView, tick) -> ShareResult:
    """2-class strict priority: the job's oldest active step is high class
    (Fig. 5 "PQ"); the low class shares what remains."""
    st, J = ctx.st, ctx.J
    w_rate = jnp.where(inst.active, inst.irate, 0.0)
    bg = background_load(ctx, tick)
    job_min_wire = jnp.full(J, BIG).at[ctx.inst_job].min(
        jnp.where(inst.active, inst.iwire, BIG))
    is_hi = inst.active & (inst.iwire <= job_min_wire[ctx.inst_job])
    hi_rate = jnp.where(is_hi, inst.irate, 0.0)
    off_hi = inst.link_sum(ctx, hi_rate) + bg
    s_hi = jnp.minimum(1.0, st.cap / jnp.maximum(off_hi, 1.0))
    rem = jnp.maximum(st.cap - off_hi * s_hi, 0.0)
    lo_rate = jnp.where(inst.active & ~is_hi, inst.irate, 0.0)
    off_lo = inst.link_sum(ctx, lo_rate)
    s_lo = rem / jnp.maximum(off_lo, 1.0)
    share = jnp.where(is_hi[:, None], s_hi[inst.iroute],
                      jnp.minimum(1.0, s_lo[inst.iroute]))
    eff_scale = share.min(axis=1)
    return ShareResult(eff=w_rate * eff_scale, offered=off_hi + off_lo)


def share_wfq(ctx: EngineCtx, cfg, inst: InstView, tick) -> ShareResult:
    """Weighted fair sharing: each link divides its post-background capacity
    over active instances proportionally to their job's weight
    (``Static.job_weight``); an instance is capped at its own rate and takes
    the worst per-hop allowance (one-shot water-filling approximation)."""
    st = ctx.st
    w_rate = jnp.where(inst.active, inst.irate, 0.0)
    bg = background_load(ctx, tick)
    wgt = st.job_weight[ctx.inst_job]
    w_act = jnp.where(inst.active, wgt, 0.0)
    wsum = inst.link_sum(ctx, w_act)
    avail = jnp.maximum(st.cap - bg, 0.0)
    fair = avail / jnp.maximum(wsum, 1e-9)           # bytes/s per unit weight
    allowed = wgt[:, None] * fair[inst.iroute]       # [FW, H]
    eff = jnp.minimum(w_rate, allowed.min(axis=1))
    return ShareResult(eff=eff, offered=inst.link_sum(ctx, w_rate) + bg)


def share_drr(ctx: EngineCtx, cfg, inst: InstView, tick) -> ShareResult:
    """Deficit round-robin (fluid approximation): every link serves its
    active instances an equal per-round quantum regardless of job, and the
    deficit left by rate-limited instances is redistributed to the still-
    hungry ones in a second round (two-round water-filling)."""
    st = ctx.st
    w_rate = jnp.where(inst.active, inst.irate, 0.0)
    bg = background_load(ctx, tick)
    n_act = inst.link_sum(ctx, inst.active.astype(jnp.float32))
    avail = jnp.maximum(st.cap - bg, 0.0)
    quantum = avail / jnp.maximum(n_act, 1.0)
    take1 = jnp.minimum(w_rate, inst.path_min(quantum))
    used = inst.link_sum(ctx, take1)
    want = inst.active & (take1 < w_rate)
    n_want = inst.link_sum(ctx, want.astype(jnp.float32))
    bonus = jnp.maximum(avail - used, 0.0) / jnp.maximum(n_want, 1.0)
    take2 = jnp.where(want,
                      jnp.minimum(w_rate - take1, inst.path_min(bonus)), 0.0)
    return ShareResult(eff=take1 + take2,
                       offered=inst.link_sum(ctx, w_rate) + bg)


SHARE_POLICIES: dict[str, Callable[..., ShareResult]] = {
    "proportional": share_proportional,
    "pq": share_pq,
    "wfq": share_wfq,
    "drr": share_drr,
}


# --------------------------------------------------------- 4. queues + RED
def stage_queues(ctx: EngineCtx, cfg, q_prev, offered):
    """Integrate per-link queues and derive the RED marking profile."""
    q = jnp.maximum(q_prev + (offered - ctx.st.cap) * cfg.dt, 0.0)
    q = q.at[ctx.L].set(0.0)
    p_red = jnp.clip((q - cfg.red_kmin) / (cfg.red_kmax - cfg.red_kmin),
                     0.0, 1.0) * cfg.red_pmax
    return q, p_red


# ------------------------------------------------------------- 5. marking
def stage_marking(ctx: EngineCtx, cfg, state: EngineState, inst: InstView,
                  p_red, eff, lam, tick):
    """Combine RED with Symphony's selective marking along each path into
    the per-instance expected-mark accumulator lambda."""
    D = ctx.D
    sm = state.s_stepmin[inst.dj]
    pw = state.s_psnwin[inst.dj]
    al = state.s_alpha[inst.dj]
    # sym_on is a traced 0/1 gate (RuntimeKnobs): the marking math is always
    # in the program and selected at runtime, so one compile serves both the
    # baseline and the Symphony points of a knob grid.
    p_sym = marking_probability(
        inst.iwire[:, None], inst.ipsn[:, None], sm, pw, al, cfg.sym)
    p_sym = jnp.where(inst.idom < D, p_sym, 0.0)
    sym_gate = (jnp.asarray(cfg.sym_on) != 0) & (tick >= cfg.sym_start_tick)
    p_sym = jnp.where(sym_gate, p_sym, 0.0)
    p_hop = 1.0 - (1.0 - p_red[inst.iroute]) * (1.0 - p_sym)
    log_nomark = jnp.sum(jnp.log1p(-jnp.minimum(p_hop, 0.999999)), axis=1)
    p_inst = 1.0 - jnp.exp(log_nomark)
    pkts = eff * cfg.dt / cfg.mtu
    lam = (lam.reshape(ctx.FW) +
           jnp.where(inst.active, p_inst * pkts, 0.0)).reshape(ctx.F, ctx.W)
    return lam, pkts, sm


# ------------------------------------------------------------ 6. progress
def stage_progress(ctx: EngineCtx, cfg, state: EngineState, inst: InstView,
                   step_of, eff, tick):
    """Advance per-instance bytes, retire completed steps in order, record
    per-slot finish ticks."""
    wl, fidx = ctx.wl, ctx.fidx
    isent_new = inst.isent + eff * cfg.dt
    newly_done = inst.active & (isent_new >= inst.ichunk)
    sent = isent_new.reshape(ctx.F, ctx.W)
    done_upto = state.done_upto
    for _ in range(2):  # <=2 completions per slot per tick in practice
        wsel = done_upto % ctx.W
        ch = ctx.chunk_of(
            wl.job, seg_global(done_upto, wl.sps, wl.phase, ctx.nph_f))
        ok = (step_of[fidx, wsel] == done_upto) & (sent[fidx, wsel] >= ch)
        done_upto = done_upto + ok.astype(jnp.int32)
    finish = jnp.where((done_upto >= wl.total_steps) &
                       (state.finish == I32MAX), tick, state.finish)
    return sent, done_upto, finish, newly_done


# ------------------------------------------------------ 7. Symphony state
def stage_symphony(ctx: EngineCtx, cfg, state: EngineState, inst: InstView,
                   sm, pkts, newly_done, eff, tick):
    """Per-(domain, job) state blocks: traffic stats, optimistic step-min
    advancement with lazy correction, windowed alpha update (Alg. 1)."""
    H, DJ = ctx.H, (ctx.D + 1) * ctx.J
    # one scatter entry per (instance, hop); hops in the null domain D
    # land on rows >= D*J and are ignored by marking.
    act4 = jnp.repeat(inst.active, H)
    send4 = jnp.repeat(inst.active & (eff > 1.0), H)
    done4 = jnp.repeat(newly_done, H)
    wire4 = jnp.repeat(inst.iwire, H)
    psn4 = jnp.repeat(inst.ipsn + pkts, H)
    pkts4 = jnp.repeat(pkts, H)
    sm4 = sm.reshape(-1)
    djf = inst.djf

    cnt = state.s_cnt.at[djf].add(jnp.where(act4, pkts4, 0.0))
    cntop = state.s_cntop.at[djf].add(
        jnp.where(act4 & (wire4 > sm4), pkts4, 0.0))
    # optimistic advancement on LAST events, then lazy correction
    cand = jnp.zeros(DJ, jnp.int32).at[djf].max(
        jnp.where(done4, wire4 + 1, 0))
    cand = jnp.maximum(state.s_stepmin, cand)
    min_act = jnp.full(DJ, BIG).at[djf].min(
        jnp.where(act4 & ~done4, wire4, BIG))
    stepmin = jnp.where(min_act < BIG, jnp.minimum(cand, min_act), cand)
    psnwin = state.s_psnwin.at[djf].max(
        jnp.where(send4 & ~done4 & (wire4 == stepmin[djf]), psn4, 0.0))

    sym_epoch = (tick % cfg.sym_win_ticks) == (cfg.sym_win_ticks - 1)
    have = cnt > jnp.asarray(cfg.sym.n_sample, jnp.float32)
    exceed = cntop >= jnp.asarray(cfg.sym.tau, jnp.float32) * cnt
    alpha_new = jnp.clip(state.s_alpha + jnp.where(exceed, 1.0, -1.0) * have,
                         1.0, jnp.asarray(cfg.sym.alpha_max, jnp.float32))
    s_alpha = jnp.where(sym_epoch, alpha_new, state.s_alpha)
    s_cnt = jnp.where(sym_epoch, 0.0, cnt)
    s_cntop = jnp.where(sym_epoch, 0.0, cntop)
    s_psnwin = jnp.where(sym_epoch, 0.0, psnwin)
    return stepmin, s_psnwin, s_alpha, s_cnt, s_cntop


# -------------------------------------------------------- 8. rate control
def stage_rate_control(ctx: EngineCtx, cfg, starts: Starts, lam, key, tick):
    """DCQCN-style epoch update driven by the accumulated mark probability."""
    F, W = ctx.F, ctx.W
    line_rate = ctx.line_rate
    step_of = starts.step_of
    cc_epoch = (tick % cfg.cc_epoch_ticks) == (cfg.cc_epoch_ticks - 1)

    def cc_update(args):
        rate, target, alpha_cc, stage, lam, key = args
        key, sub = jax.random.split(key)
        u = jax.random.uniform(sub, (F, W))
        cut = (u < 1.0 - jnp.exp(-lam)) & (step_of >= 0)
        r_c = jnp.maximum(rate * (1.0 - alpha_cc / 2.0), cfg.cc_min_rate)
        # DCQCN: the recovery target snapshots the current rate on the
        # *first* cut of a congestion event only; consecutive cuts
        # (stage==0) keep the previous target so fast recovery can bounce
        # back to the pre-congestion operating point.
        t_c = jnp.where(stage > 0, rate, target)
        a_c = (1.0 - cfg.cc_g) * alpha_cc + cfg.cc_g
        a_n = (1.0 - cfg.cc_g) * alpha_cc
        stage_n = stage + 1
        tgt_inc = jnp.where(stage_n > cfg.cc_fr_stages,
                            jnp.where(stage_n > 2 * cfg.cc_fr_stages,
                                      cfg.cc_rhai, cfg.cc_rai), 0.0)
        t_n = jnp.minimum(target + tgt_inc, line_rate[:, None])
        r_n = jnp.minimum((rate + t_n) / 2.0, line_rate[:, None])
        return (jnp.where(cut, r_c, r_n), jnp.where(cut, t_c, t_n),
                jnp.where(cut, a_c, a_n), jnp.where(cut, 0, stage_n),
                jnp.zeros_like(lam), key)

    return jax.lax.cond(
        cc_epoch, cc_update, lambda a: a,
        (starts.rate, starts.target, starts.alpha_cc, starts.stage, lam, key))


# ----------------------------------------------------- 9. segments / jobs
def stage_segments(ctx: EngineCtx, state: EngineState, done_upto, tick):
    """Advance the job-wide segment barrier and record job finish ticks."""
    wl, J = ctx.wl, ctx.J
    seg_phase = state.seg_idx % wl.n_phases
    participating = wl.phase == seg_phase[wl.job]
    c_end = (state.seg_idx[wl.job] // ctx.nph_f + 1) * wl.sps
    flow_done = ((~participating) | (done_upto >= c_end)).astype(jnp.int32)
    seg_done = jnp.ones(J, jnp.int32).at[wl.job].min(flow_done) > 0
    adv = seg_done & (state.seg_idx < wl.n_segs) & (tick >= state.seg_ready)
    seg_idx = state.seg_idx + adv.astype(jnp.int32)
    new_phase0 = (seg_idx % wl.n_phases) == 0
    seg_ready = jnp.where(adv,
                          tick + jnp.where(new_phase0, wl.gap_ticks, 0),
                          state.seg_ready)
    job_finish = jnp.where((seg_idx >= wl.n_segs) &
                           (state.job_finish == I32MAX),
                           tick, state.job_finish)
    # Dependency-triggered arrivals: a pending job (seg_ready still at the
    # I32MAX sentinel) is released once its trigger job's segment barrier
    # has advanced past the required count.  Integer-only, so untriggered
    # workloads (trig_job == -1 everywhere) stay bit-for-bit unchanged.
    trig_src = jnp.clip(wl.trig_job, 0, J - 1)
    fired = (wl.trig_job >= 0) & (state.seg_ready == I32MAX) & \
            (seg_idx[trig_src] >= wl.trig_seg)
    seg_ready = jnp.where(fired,
                          tick + wl.trig_delay_ticks + wl.gap_ticks,
                          seg_ready)
    return seg_idx, seg_ready, job_finish


# ------------------------------------------------------------ 10. metrics
def stage_metrics(ctx: EngineCtx, inst: InstView, done_upto, eff, q, s_alpha):
    """The sampled observables of one tick."""
    J, L = ctx.J, ctx.L
    min_wire = jnp.full(J, BIG).at[ctx.inst_job].min(
        jnp.where(inst.active, inst.iwire, BIG))
    max_wire = jnp.full(J, -1).at[ctx.inst_job].max(
        jnp.where(inst.active, inst.iwire, -1))
    done_min = jnp.full(J, BIG).at[ctx.wl.job].min(done_upto)
    # masked sum, not scatter-add: vmap batching rewrites scatter-add
    # accumulation order (ULP drift), while a fixed-axis reduction keeps
    # grid slices bitwise-equal to single runs.  J is small, so the dense
    # [J, FW] mask is cheap.
    tput = jnp.sum(
        jnp.where(ctx.inst_job[None, :] == jnp.arange(J)[:, None],
                  eff[None, :], 0.0), axis=1)
    return (min_wire, max_wire, done_min, tput, q[:L].max(), s_alpha.max())


# ------------------------------------------------------------ composition
def static_pq_on(cfg):
    """``pq_on`` as a Python bool when static, else None (traced gate)."""
    pq = getattr(cfg, "pq_on", False)
    if isinstance(pq, jax.Array):
        return None
    return bool(pq)


def resolve_share_policy(cfg) -> Callable[..., ShareResult]:
    pq = static_pq_on(cfg)
    if pq and cfg.share_policy not in ("proportional", "pq"):
        raise ValueError(
            f"pq_on=True conflicts with share_policy={cfg.share_policy!r}; "
            "drop the legacy pq_on flag when selecting a policy explicitly")
    name = "pq" if pq else cfg.share_policy
    try:
        return SHARE_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown share policy {name!r}; have {sorted(SHARE_POLICIES)}")


def stage_share(ctx: EngineCtx, cfg, inst: InstView, tick) -> ShareResult:
    """Bandwidth sharing with the runtime ``pq_on`` override.

    The base policy is static (``cfg.share_policy`` names the compiled
    program).  A traced ``pq_on`` gate switches to strict priority via
    ``lax.cond``: a scalar predicate executes one branch at runtime; under
    vmap (knob grids) it lowers to a select so mixed baseline/PQ grids
    still share one compilation.
    """
    base_fn = resolve_share_policy(cfg)
    if static_pq_on(cfg) is not None:   # legacy all-static config
        return base_fn(ctx, cfg, inst, tick)
    if base_fn is share_pq:
        return share_pq(ctx, cfg, inst, tick)
    return jax.lax.cond(
        jnp.asarray(cfg.pq_on) != 0,
        lambda: share_pq(ctx, cfg, inst, tick),
        lambda: base_fn(ctx, cfg, inst, tick))


BACKENDS = ("xla", "pallas")
_FALLBACK_WARNED: set = set()


def resolve_backend(cfg) -> str:
    """The tick backend actually used for this config.

    ``backend="pallas"`` fuses route-gather / bandwidth-share / queue-RED /
    Symphony-scatter into the ``kernels/netsim_tick`` Pallas kernel.  The
    kernel implements the ``proportional`` and ``pq`` share paths (plus the
    traced ``pq_on`` gate); ``wfq``/``drr`` fall back to the staged XLA
    path behind this same dispatch, logged once per policy via
    ``warnings.warn``.
    """
    be = getattr(cfg, "backend", "xla")
    if be not in BACKENDS:
        raise ValueError(f"unknown tick backend {be!r}; have {BACKENDS}")
    if be == "pallas" and cfg.share_policy not in ("proportional", "pq"):
        if cfg.share_policy not in _FALLBACK_WARNED:
            _FALLBACK_WARNED.add(cfg.share_policy)
            warnings.warn(
                f"backend='pallas' with share_policy={cfg.share_policy!r} "
                "falls back to the staged XLA tick: the fused kernel only "
                "implements the proportional/pq share paths",
                stacklevel=2)
        return "xla"
    return be


def engine_tick(ctx: EngineCtx, cfg, state: EngineState, tick):
    """One tick: compose the stages.  Returns (state', metric sample).

    Dispatches on ``cfg.backend`` (static, from :class:`SimStructure`):
    ``"xla"`` runs the staged composition below; ``"pallas"`` routes the
    hot stages through the fused ``kernels/netsim_tick`` kernel and keeps
    this composition as its golden reference.
    """
    if resolve_backend(cfg) == "pallas":
        from ...kernels.netsim_tick.ops import engine_tick_fused
        return engine_tick_fused(ctx, cfg, state, tick)
    return engine_tick_xla(ctx, cfg, state, tick)


def engine_tick_xla(ctx: EngineCtx, cfg, state: EngineState, tick):
    """The pure-XLA staged tick (the reference semantics of the engine)."""
    starts = stage_starts(ctx, state, tick)
    inst = instance_view(ctx, starts, state, cfg.mtu, cfg.per_step_ecmp)
    shr = stage_share(ctx, cfg, inst, tick)
    q, p_red = stage_queues(ctx, cfg, state.q, shr.offered)
    lam, pkts, sm = stage_marking(ctx, cfg, state, inst, p_red, shr.eff,
                                  starts.lam, tick)
    sent, done_upto, finish, newly_done = stage_progress(
        ctx, cfg, state, inst, starts.step_of, shr.eff, tick)
    stepmin, s_psnwin, s_alpha, s_cnt, s_cntop = stage_symphony(
        ctx, cfg, state, inst, sm, pkts, newly_done, shr.eff, tick)
    rate, target, alpha_cc, stage, lam, key = stage_rate_control(
        ctx, cfg, starts, lam, state.key, tick)
    seg_idx, seg_ready, job_finish = stage_segments(ctx, state, done_upto,
                                                    tick)
    sample = stage_metrics(ctx, inst, done_upto, shr.eff, q, s_alpha)
    new_state = EngineState(
        next_step=starts.next_step, done_upto=done_upto, finish=finish,
        step_of=starts.step_of, sent=sent, rate=rate, target=target,
        alpha_cc=alpha_cc, stage=stage, lam=lam, q=q,
        s_stepmin=stepmin, s_psnwin=s_psnwin, s_alpha=s_alpha,
        s_cnt=s_cnt, s_cntop=s_cntop,
        seg_idx=seg_idx, seg_ready=seg_ready, job_finish=job_finish,
        key=key,
    )
    return new_state, sample
