"""Online control plane: a ``step(state, action) -> (state, obs)`` API.

Symphony is an *online* mechanism — it reads live congestion signals and
throttles outpacing flows mid-collective — and this module gives the
repo's engine the matching interface.  A simulation is no longer a
closed one-shot scan: :class:`SimController` owns a checkpointable
:class:`~repro.core.netsim.params.SimState`, advances it one *control
window* at a time through :func:`~repro.core.netsim.simulator.run_window`
(one ``lax.scan`` chunk, compiled once, reused across windows), and lets
every window retune :class:`~repro.core.netsim.params.RuntimeKnobs`
fields via :func:`apply_action` — a pure pytree update on traced leaves,
so knob changes between windows NEVER retrace (``core_trace_count``
advances by exactly 1 across any number of steps).

Gym-flavored usage (cf. RealVNF's ``SimulatorInterface`` in PAPERS.md)::

    ctl = SimController(topo, wl, cfg, window_ticks=640, seed=3)
    state, obs = ctl.step()                      # run one window
    while not obs.done:
        action = {"tau": policy(obs), "k": 0.02}
        state, obs = ctl.step(action)            # retune mid-flight, free

``obs`` carries the per-window alpha/queue/throughput summaries from
:mod:`repro.core.netsim.metrics` plus job-completion flags; ``state`` is
the full resumable checkpoint (``jax.device_get`` it to snapshot,
:meth:`SimController.restore` to rewind — resuming is bit-for-bit
identical to never having paused).
"""
from __future__ import annotations

from typing import Mapping, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import metrics
from .params import RuntimeKnobs, SimParams, SimState, SimStructure
from .simulator import (I32MAX, Static, WindowSamples, _resolve_routing,
                        build_static, init_state, run_window, wl_arrays)
from .topology import Topology
from .workload import Workload

__all__ = ["ACTION_FIELDS", "StepObs", "SimController", "apply_action"]

# Symphony shortcuts: action keys rewriting knobs.sym.<field>.  Every
# top-level RuntimeKnobs field name (red_pmax, cc_g, sym_on, pq_on,
# sym_win_ticks, ...) is also a valid action key.
_SYM_FIELDS = ("k", "tau", "n_warmup", "n_sample", "alpha_max")
ACTION_FIELDS = tuple(f for f in RuntimeKnobs._fields if f != "sym") \
    + _SYM_FIELDS


def apply_action(knobs: RuntimeKnobs, action: Mapping[str, float]
                 ) -> RuntimeKnobs:
    """Retune knob values from an action dict — a pure pytree update.

    Keys are top-level :class:`RuntimeKnobs` fields (``"red_pmax"``,
    ``"sym_on"``, ``"sym_win_ticks"``, ...) or Symphony shortcuts
    (``"tau"``, ``"k"``, ``"alpha_max"``, ``"n_warmup"``,
    ``"n_sample"``) that rewrite ``knobs.sym``.  New values are cast to
    the existing leaf's dtype, so the updated pytree has the identical
    structure/dtypes and a jitted consumer never retraces.
    """
    sym = knobs.sym
    top: dict = {}
    sym_upd: dict = {}
    for name, val in action.items():
        if name in _SYM_FIELDS:
            sym_upd[name] = val
        elif name == "sym":
            raise ValueError(
                "set Symphony fields individually (tau/k/alpha_max/"
                "n_warmup/n_sample), not the whole 'sym' bundle")
        elif name in RuntimeKnobs._fields:
            top[name] = val
        else:
            raise ValueError(
                f"unknown action field {name!r}; have {ACTION_FIELDS}")

    def cast(old, new):
        leaf = jnp.asarray(old)
        return jnp.asarray(new, leaf.dtype)

    if sym_upd:
        sym = sym._replace(**{k: cast(getattr(sym, k), v)
                              for k, v in sym_upd.items()})
    return knobs._replace(
        sym=sym, **{k: cast(getattr(knobs, k), v) for k, v in top.items()})


class StepObs(NamedTuple):
    """What one control window observed (host-side numpy)."""
    tick: int                      # tick cursor AFTER this window
    t: float                       # same, in simulated seconds
    stats: metrics.WindowStats     # alpha/queue/throughput summaries
    samples: WindowSamples         # the window's raw sampled series
    job_finished: np.ndarray       # [J] bool
    done: bool                     # every job finished


class SimController:
    """Stateful windowed driver over ``init_state`` / ``run_window``.

    Owns the :class:`Static` arrays, the current :class:`RuntimeKnobs`,
    and the resumable :class:`SimState`; every :meth:`step` advances one
    control window and returns ``(state, obs)``.  The windowed engine
    compiles ONCE per ``(structure, window_ticks)`` and is reused across
    steps, actions, and even controller instances.
    """

    def __init__(self, topo: Topology, wl: Workload, cfg: SimParams,
                 *, window_ticks: int | None = None, routing: str = "ecmp",
                 seed: int = 0, bg_base=None, bg_amp=None, bg_period=1e-3,
                 bg_duty=0.0, job_weight=None):
        cfg, mode = _resolve_routing(cfg, routing)
        if isinstance(cfg, SimParams):
            struct, knobs = cfg.split()
        else:                         # a SimStructure: default knob values
            struct, knobs = cfg, SimParams().knobs()
        self.struct: SimStructure = struct
        self.knobs: RuntimeKnobs = knobs
        self.wl = wl
        self.st: Static = build_static(
            topo, wl, mode, seed, bg_base, bg_amp, bg_period, bg_duty,
            struct.dt, deploy=struct.deploy, job_weight=job_weight)
        self.wla = wl_arrays(wl, struct.dt)
        R = struct.record_every
        w = R if window_ticks is None else int(window_ticks)
        if w <= 0 or w % R:
            raise ValueError(
                f"window_ticks must be a positive multiple of "
                f"record_every={R}, got {window_ticks}")
        self.window_ticks = w
        self._seed = seed
        self.state: SimState = init_state(
            self.st, self.wla, struct, jax.random.PRNGKey(seed))

    # ------------------------------------------------------------- control
    def step(self, action: Mapping[str, float] | None = None,
             n_ticks: int | None = None) -> tuple[SimState, StepObs]:
        """Apply ``action`` (optional knob retunes), run one window."""
        if action:
            self.knobs = apply_action(self.knobs, action)
        self.state, samples = run_window(
            self.st, self.wla, self.struct, self.knobs, self.state,
            self.window_ticks if n_ticks is None else n_ticks)
        jf = np.asarray(self.state.engine.job_finish)
        finished = jf != I32MAX
        tick = int(self.state.tick)
        obs = StepObs(
            tick=tick, t=tick * self.struct.dt,
            stats=metrics.window_summary(samples), samples=samples,
            job_finished=finished, done=bool(finished.all()))
        return self.state, obs

    def run(self, n_windows: int,
            policy=None) -> StepObs:
        """Convenience driver: ``n_windows`` steps (or until done);
        ``policy(obs) -> action|None`` is consulted after each window."""
        obs = None
        action = None
        for _ in range(n_windows):
            _, obs = self.step(action)
            if obs.done:
                break
            action = policy(obs) if policy is not None else None
        return obs

    # ---------------------------------------------------- checkpoint/resume
    def checkpoint(self) -> SimState:
        """A host-side snapshot of the current state (device_get'd, so it
        survives donation/aliasing on the pallas window path)."""
        return jax.device_get(self.state)

    def restore(self, state: SimState) -> None:
        """Rewind/teleport to a checkpointed state."""
        self.state = jax.tree.map(jnp.asarray, state)

    def reset(self, seed: int | None = None) -> SimState:
        """Back to tick 0 (optionally reseeding the CC coin flips)."""
        if seed is not None:
            self._seed = seed
        self.state = init_state(
            self.st, self.wla, self.struct, jax.random.PRNGKey(self._seed))
        return self.state
