from . import control, metrics, params, stages, topology, workload
from .control import SimController, StepObs, apply_action
from .params import (EngineParams, RuntimeKnobs, SimParams, SimState,
                     SimStructure, grid_from_params, merge_params,
                     stack_knobs)
from .simulator import (GRID_AXIS, SimResult, Static, WindowSamples,
                        build_static, core_trace_count, init_state,
                        link_domains, resolve_grid_mesh, run_window,
                        simulate, simulate_core, simulate_grid,
                        simulate_seeds)
from .stages import SHARE_POLICIES, EngineCtx, EngineState
from .topology import (FatTree, LeafSpine, Topology, make_fat_tree,
                       make_leaf_spine, scale_for_hosts)
from .workload import Workload, WorkloadBuilder

__all__ = [
    "SimParams", "SimStructure", "RuntimeKnobs", "EngineParams", "SimState",
    "grid_from_params", "merge_params", "stack_knobs",
    "SimResult", "Static", "simulate", "simulate_core", "simulate_seeds",
    "simulate_grid", "core_trace_count", "build_static", "link_domains",
    "resolve_grid_mesh", "GRID_AXIS",
    "init_state", "run_window", "WindowSamples",
    "SimController", "StepObs", "apply_action",
    "SHARE_POLICIES", "EngineCtx", "EngineState",
    "Topology", "LeafSpine", "FatTree", "make_leaf_spine", "make_fat_tree",
    "scale_for_hosts",
    "Workload", "WorkloadBuilder", "control", "metrics", "params", "stages",
    "topology", "workload",
]
