from . import metrics, topology, workload
from .simulator import (SimParams, SimResult, simulate, simulate_core,
                        simulate_seeds)
from .topology import Topology, make_leaf_spine, scale_for_hosts
from .workload import Workload, WorkloadBuilder

__all__ = [
    "SimParams", "SimResult", "simulate", "simulate_core", "simulate_seeds",
    "Topology", "make_leaf_spine", "scale_for_hosts",
    "Workload", "WorkloadBuilder", "metrics", "topology", "workload",
]
