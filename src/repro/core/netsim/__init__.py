from . import metrics, params, stages, topology, workload
from .params import (EngineParams, RuntimeKnobs, SimParams, SimStructure,
                     grid_from_params, merge_params, stack_knobs)
from .simulator import (GRID_AXIS, SimResult, Static, build_static,
                        core_trace_count, link_domains, resolve_grid_mesh,
                        simulate, simulate_core, simulate_grid,
                        simulate_seeds)
from .stages import SHARE_POLICIES, EngineCtx, EngineState
from .topology import (FatTree, LeafSpine, Topology, make_fat_tree,
                       make_leaf_spine, scale_for_hosts)
from .workload import Workload, WorkloadBuilder

__all__ = [
    "SimParams", "SimStructure", "RuntimeKnobs", "EngineParams",
    "grid_from_params", "merge_params", "stack_knobs",
    "SimResult", "Static", "simulate", "simulate_core", "simulate_seeds",
    "simulate_grid", "core_trace_count", "build_static", "link_domains",
    "resolve_grid_mesh", "GRID_AXIS",
    "SHARE_POLICIES", "EngineCtx", "EngineState",
    "Topology", "LeafSpine", "FatTree", "make_leaf_spine", "make_fat_tree",
    "scale_for_hosts",
    "Workload", "WorkloadBuilder", "metrics", "params", "stages", "topology",
    "workload",
]
