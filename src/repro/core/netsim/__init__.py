from . import metrics, stages, topology, workload
from .simulator import (SimParams, SimResult, Static, build_static,
                        link_domains, simulate, simulate_core, simulate_seeds)
from .stages import SHARE_POLICIES, EngineCtx, EngineState
from .topology import (FatTree, LeafSpine, Topology, make_fat_tree,
                       make_leaf_spine, scale_for_hosts)
from .workload import Workload, WorkloadBuilder

__all__ = [
    "SimParams", "SimResult", "Static", "simulate", "simulate_core",
    "simulate_seeds", "build_static", "link_domains",
    "SHARE_POLICIES", "EngineCtx", "EngineState",
    "Topology", "LeafSpine", "FatTree", "make_leaf_spine", "make_fat_tree",
    "scale_for_hosts",
    "Workload", "WorkloadBuilder", "metrics", "stages", "topology",
    "workload",
]
