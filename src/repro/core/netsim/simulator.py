"""Fluid-flow network simulator for ring collectives (our Astra-Sim + NS-3).

One `lax.scan` over fixed ticks of `dt` seconds. All state is arrays, so the
whole simulation jits and vmaps over seeds/parameters.

Entities
--------
flow slot   f in [0, F): persistent (ring, member) sender->successor relation
instance    (f, w): one in-flight step-send of slot f. Steps pipeline (a node
            may start step s once it *received* s-1), so several instances of
            a slot can be concurrently active — this is the step-overlap
            phenomenon the paper studies (Fig. 1e). W = cfg.window slots,
            keyed by s % W.
link        rows of the Topology table + one trailing "null" link with
            infinite capacity (padding for intra-ToR routes).

Per tick
--------
1. starts: gate on segment barrier + ring data dependency + slot availability
2. link loads -> proportional (or 2-class PQ) bandwidth shares -> progress
3. queues -> RED marking; Symphony per-(link, job) state -> selective marking
4. DCQCN-style rate control per instance, driven by accumulated mark prob.
5. completions advance `done_upto`, segment barriers, and job finish times

Time is kept in integer ticks (i32) so float32 never loses precision.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..symphony import SymphonyParams, marking_probability
from .topology import Topology
from .workload import Workload, balanced_spines, ecmp_spines, routes_for

# Wire-step encoding: global segment index * WIRE_SEG + step-within-segment.
# Monotone across segments; comparable across flows inside a segment.
WIRE_SEG = 4096
I32MAX = np.iinfo(np.int32).max
BIG = jnp.int32(2**30)


class SimParams(NamedTuple):
    dt: float = 10e-6
    n_ticks: int = 20_000
    window: int = 48               # max concurrent steps per slot (W)
    mtu: float = 1000.0            # bytes per "packet" (psn unit)
    record_every: int = 20         # metric sampling period (ticks)
    # RED / ECN (bytes)
    red_kmin: float = 50e3
    red_kmax: float = 100e3
    red_pmax: float = 0.2
    # DCQCN-style rate control
    cc_epoch_ticks: int = 5        # 50 us control epoch
    cc_g: float = 1.0 / 16.0
    cc_rai: float = 5e6            # additive increase (bytes/s) = 40 Mb/s
    cc_rhai: float = 25e6          # hyper increase
    cc_fr_stages: int = 5
    cc_min_rate: float = 1.25e5    # 1 Mb/s floor (paper §5 "soft limit")
    # Symphony
    sym_on: bool = False
    sym: SymphonyParams = SymphonyParams()
    sym_win_ticks: int = 10        # T_win = 100 us
    sym_start_tick: int = 0        # late-start experiments (Fig. 4)
    # Alternatives / knobs
    pq_on: bool = False            # strict-priority for lagging flows (Fig. 5)
    per_step_ecmp: bool = True     # re-hash the 5-tuple every step (§4.7: the
                                   # step index lives in the UDP sport, so each
                                   # step is a distinct flow to ECMP)


class SimResult(NamedTuple):
    finish_ticks: jax.Array        # [F] completion tick per flow slot (I32MAX if not)
    job_finish_ticks: jax.Array    # [J]
    # sampled series, every record_every ticks:
    ts_min_wire: jax.Array         # [T, J] oldest active wire step (BIG if none)
    ts_max_wire: jax.Array         # [T, J] newest active wire step (-1 if none)
    ts_done_min: jax.Array         # [T, J] min completed local steps over flows
    ts_throughput: jax.Array       # [T, J] delivered bytes/s summed over job
    ts_qmax: jax.Array             # [T]    max queue depth (bytes)
    ts_alpha_max: jax.Array        # [T]    max Symphony alpha over ports


class Static(NamedTuple):
    """Per-run device arrays (vmap over leading axis for multi-seed)."""
    routes: jax.Array        # [F, 4] link ids (per-flow / balanced routing)
    cap: jax.Array           # [L+1] bytes/s
    link_dom: jax.Array      # [L+1] Symphony domain (switch) id; D = no Symphony
    dom_pad: jax.Array       # [D+1] zeros; carries the static domain count
    bg_base: jax.Array       # [L+1] bytes/s constant background load
    bg_amp: jax.Array        # [L+1] square-wave background amplitude
    bg_period_ticks: jax.Array  # i32 scalar
    bg_duty: jax.Array          # f32 scalar in [0,1]
    # per-step ECMP support
    src_tor: jax.Array       # [F]
    dst_tor: jax.Array       # [F]
    hts: jax.Array           # [3] = (n_hosts, n_tors, n_spines)
    seed: jax.Array          # i32 hash salt


def link_domains(topo: Topology) -> np.ndarray:
    """Map each link to the switch owning its egress port.  Symphony is
    deployed on ToR switches only (paper §5 "Practical deployment"): ToR
    egress = access-down links + ToR->spine uplinks. Everything else (host
    NICs, spine egress) maps to the null domain D = n_tors."""
    H, T, S = topo.n_hosts, topo.n_tors, topo.n_spines
    dom = np.full(topo.n_links + 1, T, np.int32)
    hosts = np.arange(H)
    dom[topo.acc_down(hosts)] = topo.tor_of(hosts)
    for t in range(T):
        dom[topo.uplink(t, np.arange(S))] = t
    return dom


def build_static(topo: Topology, wl: Workload, routing: str, seed: int,
                 bg_base: np.ndarray | None = None,
                 bg_amp: np.ndarray | None = None,
                 bg_period: float = 1e-3, bg_duty: float = 0.0,
                 dt: float = 10e-6) -> Static:
    if routing == "ecmp":
        spine = ecmp_spines(topo, wl, seed)
    elif routing == "balanced":
        spine = balanced_spines(topo, wl)
    else:
        raise ValueError(routing)
    routes = routes_for(topo, wl, spine)
    zb = np.zeros(topo.n_links + 1)
    return Static(
        routes=jnp.asarray(routes, jnp.int32),
        cap=jnp.asarray(np.concatenate([topo.link_cap, [1e30]]), jnp.float32),
        link_dom=jnp.asarray(link_domains(topo)),
        dom_pad=jnp.zeros(topo.n_tors + 1, jnp.float32),
        bg_base=jnp.asarray(zb if bg_base is None else np.append(bg_base, 0.0),
                            jnp.float32),
        bg_amp=jnp.asarray(zb if bg_amp is None else np.append(bg_amp, 0.0),
                           jnp.float32),
        bg_period_ticks=jnp.asarray(max(1, round(bg_period / dt)), jnp.int32),
        bg_duty=jnp.asarray(bg_duty, jnp.float32),
        src_tor=jnp.asarray(topo.tor_of(wl.src), jnp.int32),
        dst_tor=jnp.asarray(topo.tor_of(wl.dst), jnp.int32),
        hts=jnp.asarray([topo.n_hosts, topo.n_tors, topo.n_spines], jnp.int32),
        seed=jnp.asarray(seed, jnp.int32),
    )


class WLArrays(NamedTuple):
    src: jax.Array; dst: jax.Array; pred: jax.Array; job: jax.Array
    phase: jax.Array; sps: jax.Array; pass_steps: jax.Array
    total_steps: jax.Array
    n_phases: jax.Array; n_segs: jax.Array; chunk_sched: jax.Array
    gap_ticks: jax.Array; start_ticks: jax.Array
    step_offset: jax.Array; fstart_ticks: jax.Array


def wl_arrays(wl: Workload, dt: float) -> WLArrays:
    return WLArrays(
        src=jnp.asarray(wl.src), dst=jnp.asarray(wl.dst),
        pred=jnp.asarray(wl.pred), job=jnp.asarray(wl.job),
        phase=jnp.asarray(wl.phase), sps=jnp.asarray(wl.steps_per_seg),
        pass_steps=jnp.asarray(wl.pass_steps),
        total_steps=jnp.asarray(wl.total_steps()),
        n_phases=jnp.asarray(wl.n_phases),
        n_segs=jnp.asarray(wl.n_passes * wl.n_phases),
        chunk_sched=jnp.asarray(wl.chunk_sched, jnp.float32),
        gap_ticks=jnp.asarray(np.round(wl.compute_gap / dt), jnp.int32),
        start_ticks=jnp.asarray(np.round(wl.start_time / dt), jnp.int32),
        step_offset=jnp.asarray(wl.step_offset),
        fstart_ticks=jnp.asarray(np.round(wl.flow_start / dt), jnp.int32),
    )


class _State(NamedTuple):
    # slot level [F]
    next_step: jax.Array; done_upto: jax.Array; finish: jax.Array
    # instance level [F, W]
    step_of: jax.Array; sent: jax.Array
    rate: jax.Array; target: jax.Array; alpha_cc: jax.Array; stage: jax.Array
    lam: jax.Array                     # accumulated expected marks this epoch
    # link level [L+1]
    q: jax.Array
    # Symphony per (link, job), flattened [(L+1) * J]
    s_stepmin: jax.Array; s_psnwin: jax.Array; s_alpha: jax.Array
    s_cnt: jax.Array; s_cntop: jax.Array
    # job level [J]
    seg_idx: jax.Array; seg_ready: jax.Array; job_finish: jax.Array
    key: jax.Array


def _seg_global(c, sps, phase, n_phases):
    return (c // sps) * n_phases + phase


def _wire(c, sps, phase, n_phases):
    return _seg_global(c, sps, phase, n_phases) * WIRE_SEG + (c % sps)


@functools.partial(jax.jit, static_argnames=("cfg",))
def simulate_core(st: Static, wl: WLArrays, cfg: SimParams,
                  key: jax.Array) -> SimResult:
    F = int(wl.src.shape[0])
    J = int(wl.n_phases.shape[0])
    W = cfg.window
    L = int(st.cap.shape[0]) - 1
    FW = F * W
    D = int(st.dom_pad.shape[-1]) - 1   # null domain id (static)
    DJ = (D + 1) * J

    nph_f = wl.n_phases[wl.job]                          # [F]
    line_rate = st.cap[st.routes[:, 0]]                  # [F] access-link rate
    fidx = jnp.arange(F)
    inst_job = jnp.broadcast_to(wl.job[:, None], (F, W)).reshape(FW)
    inst_flow = jnp.broadcast_to(fidx[:, None], (F, W)).reshape(FW)
    sps_i = jnp.broadcast_to(wl.sps[:, None], (F, W)).reshape(FW)
    phase_i = jnp.broadcast_to(wl.phase[:, None], (F, W)).reshape(FW)
    nph_i = jnp.broadcast_to(nph_f[:, None], (F, W)).reshape(FW)
    off_i = jnp.broadcast_to(wl.step_offset[:, None], (F, W)).reshape(FW)
    iroute_static = jnp.broadcast_to(st.routes[:, None, :], (F, W, 4)).reshape(FW, 4)
    max_seg = int(wl.chunk_sched.shape[1])

    def chunk_of(job_ids, seg):
        return wl.chunk_sched[job_ids, jnp.clip(seg, 0, max_seg - 1)]

    state0 = _State(
        next_step=jnp.zeros(F, jnp.int32),
        done_upto=jnp.zeros(F, jnp.int32),
        finish=jnp.full(F, I32MAX, jnp.int32),
        step_of=jnp.full((F, W), -1, jnp.int32),
        sent=jnp.zeros((F, W), jnp.float32),
        rate=jnp.zeros((F, W), jnp.float32) + line_rate[:, None],
        target=jnp.zeros((F, W), jnp.float32) + line_rate[:, None],
        alpha_cc=jnp.ones((F, W), jnp.float32),
        stage=jnp.zeros((F, W), jnp.int32),
        lam=jnp.zeros((F, W), jnp.float32),
        q=jnp.zeros(L + 1, jnp.float32),
        s_stepmin=jnp.zeros(DJ, jnp.int32),
        s_psnwin=jnp.zeros(DJ, jnp.float32),
        s_alpha=jnp.ones(DJ, jnp.float32),
        s_cnt=jnp.zeros(DJ, jnp.float32),
        s_cntop=jnp.zeros(DJ, jnp.float32),
        seg_idx=jnp.zeros(J, jnp.int32),
        seg_ready=wl.start_ticks + wl.gap_ticks,
        job_finish=jnp.full(J, I32MAX, jnp.int32),
        key=key,
    )

    def tick_fn(state: _State, tick: jax.Array):
        # ------------------------------------------------ 1. starts
        s_next = state.next_step
        seg_of_next = _seg_global(s_next, wl.sps, wl.phase, nph_f)
        seg_ok = (seg_of_next == state.seg_idx[wl.job]) & \
                 (tick >= state.seg_ready[wl.job])
        # Ring data dependency. Within a collective, send(s) needs only
        # recv(s-1) == predecessor's *step s-1* send completed (steps carry
        # independent chunks, so no contiguity requirement).  At a collective
        # boundary (s % pass_steps == 0) the node needs its previous
        # collective complete: all own sends and all receives done.
        boundary = (s_next % wl.pass_steps) == 0
        w_prev = (s_next - 1) % W
        ps_prev = state.step_of[wl.pred, w_prev]
        prev_chunk = chunk_of(
            wl.job, _seg_global(s_next - 1, wl.sps, wl.phase, nph_f))
        pred_prev_done = (state.done_upto[wl.pred] >= s_next) | \
            (ps_prev > s_next - 1) | \
            ((ps_prev == s_next - 1) &
             (state.sent[wl.pred, w_prev] >= prev_chunk))
        pass_done = (state.done_upto >= s_next) & \
            (state.done_upto[wl.pred] >= s_next)
        ring_ok = jnp.where(boundary, (s_next == 0) | pass_done, pred_prev_done)
        ring_ok &= tick >= wl.fstart_ticks
        w_next = s_next % W
        slot = state.step_of[fidx, w_next]
        slot_free = (slot < 0) | (slot < state.done_upto)
        can = (s_next < wl.total_steps) & seg_ok & ring_ok & slot_free

        def upd(arr, val):
            return arr.at[fidx, w_next].set(
                jnp.where(can, val, arr[fidx, w_next]))

        step_of = upd(state.step_of, s_next)
        sent = upd(state.sent, 0.0)
        rate = upd(state.rate, line_rate)
        target = upd(state.target, line_rate)
        alpha_cc = upd(state.alpha_cc, 1.0)
        stage = upd(state.stage, 0)
        lam = upd(state.lam, 0.0)
        next_step = jnp.where(can, s_next + 1, s_next)

        # ------------------------------------------------ instance view
        istep = step_of.reshape(FW)
        isent = sent.reshape(FW)
        irate = rate.reshape(FW)
        iseg = _seg_global(istep, sps_i, phase_i, nph_i)
        ichunk = chunk_of(inst_job, iseg)
        iwire = _wire(istep, sps_i, phase_i, nph_i) + off_i
        occupied = istep >= 0
        retired = occupied & (istep < state.done_upto[inst_flow])
        complete = occupied & (isent >= ichunk)
        active = occupied & ~complete & ~retired

        # routes: the step index is part of the 5-tuple (paper §4.7), so each
        # step re-rolls its ECMP path; otherwise routes are static per flow.
        if cfg.per_step_ecmp:
            H, T, S = st.hts[0], st.hts[1], st.hts[2]
            h = (inst_flow.astype(jnp.uint32) * jnp.uint32(2654435761)
                 + jnp.maximum(istep, 0).astype(jnp.uint32) * jnp.uint32(40503)
                 + (st.seed.astype(jnp.uint32) + 1) * jnp.uint32(2246822519))
            h = (h ^ (h >> 13)) * jnp.uint32(2654435761)
            h = h ^ (h >> 16)
            spine = (h % S.astype(jnp.uint32)).astype(jnp.int32)
            src_t = st.src_tor[inst_flow]
            dst_t = st.dst_tor[inst_flow]
            inter = src_t != dst_t
            null = jnp.int32(L)
            iroute = jnp.stack([
                wl.src[inst_flow],
                jnp.where(inter, 2 * H + src_t * S + spine, null),
                jnp.where(inter, 2 * H + T * S + spine * T + dst_t, null),
                H + wl.dst[inst_flow],
            ], axis=1)
        else:
            iroute = iroute_static
        flat_links = iroute.reshape(-1)                   # [FW*4]
        idom = st.link_dom[iroute]                        # [FW, 4]
        djf = (idom * J + inst_job[:, None]).reshape(-1)  # [FW*4]

        # ------------------------------------------------ 2. loads & shares
        w_rate = jnp.where(active, irate, 0.0)
        bg_on = (tick % st.bg_period_ticks).astype(jnp.float32) < \
            st.bg_duty * st.bg_period_ticks.astype(jnp.float32)
        bg = st.bg_base + jnp.where(bg_on, st.bg_amp, 0.0)

        if cfg.pq_on:
            # strict priority for the job's oldest active step (Fig. 5 "PQ")
            job_min_wire = jnp.full(J, BIG).at[inst_job].min(
                jnp.where(active, iwire, BIG))
            is_hi = active & (iwire <= job_min_wire[inst_job])
            hi_rate = jnp.where(is_hi, irate, 0.0)
            off_hi = jnp.zeros(L + 1).at[flat_links].add(
                jnp.repeat(hi_rate, 4)) + bg
            s_hi = jnp.minimum(1.0, st.cap / jnp.maximum(off_hi, 1.0))
            rem = jnp.maximum(st.cap - off_hi * s_hi, 0.0)
            lo_rate = jnp.where(active & ~is_hi, irate, 0.0)
            off_lo = jnp.zeros(L + 1).at[flat_links].add(jnp.repeat(lo_rate, 4))
            s_lo = rem / jnp.maximum(off_lo, 1.0)
            share = jnp.where(is_hi[:, None], s_hi[iroute],
                              jnp.minimum(1.0, s_lo[iroute]))
            eff_scale = share.min(axis=1)
            offered = off_hi + off_lo
        else:
            offered = jnp.zeros(L + 1).at[flat_links].add(
                jnp.repeat(w_rate, 4)) + bg
            s_l = jnp.minimum(1.0, st.cap / jnp.maximum(offered, 1.0))
            eff_scale = s_l[iroute].min(axis=1)
        eff = w_rate * eff_scale                          # delivered bytes/s

        # queues + RED
        q = jnp.maximum(state.q + (offered - st.cap) * cfg.dt, 0.0)
        q = q.at[L].set(0.0)
        p_red = jnp.clip((q - cfg.red_kmin) / (cfg.red_kmax - cfg.red_kmin),
                         0.0, 1.0) * cfg.red_pmax

        # ------------------------------------------------ 3. marking
        dj = idom * J + inst_job[:, None]                 # [FW, 4]
        sm = state.s_stepmin[dj]
        pw = state.s_psnwin[dj]
        al = state.s_alpha[dj]
        ipsn = isent / cfg.mtu
        if cfg.sym_on:
            p_sym = marking_probability(
                iwire[:, None], ipsn[:, None], sm, pw, al, cfg.sym)
            p_sym = jnp.where(idom < D, p_sym, 0.0)
            p_sym = jnp.where(tick >= cfg.sym_start_tick, p_sym, 0.0)
        else:
            p_sym = jnp.zeros_like(pw)
        p_hop = 1.0 - (1.0 - p_red[iroute]) * (1.0 - p_sym)
        log_nomark = jnp.sum(jnp.log1p(-jnp.minimum(p_hop, 0.999999)), axis=1)
        p_inst = 1.0 - jnp.exp(log_nomark)
        pkts = eff * cfg.dt / cfg.mtu
        lam = (lam.reshape(FW) +
               jnp.where(active, p_inst * pkts, 0.0)).reshape(F, W)

        # ------------------------------------------------ 4. progress
        isent_new = isent + eff * cfg.dt
        newly_done = active & (isent_new >= ichunk)
        sent = isent_new.reshape(F, W)

        done_upto = state.done_upto
        for _ in range(2):  # <=2 completions per slot per tick in practice
            wsel = done_upto % W
            ch = chunk_of(wl.job, _seg_global(done_upto, wl.sps, wl.phase, nph_f))
            ok = (step_of[fidx, wsel] == done_upto) & (sent[fidx, wsel] >= ch)
            done_upto = done_upto + ok.astype(jnp.int32)
        finish = jnp.where((done_upto >= wl.total_steps) &
                           (state.finish == I32MAX), tick, state.finish)

        # ------------------------------------------------ 5. Symphony state
        # one scatter entry per (instance, hop); hops in the null domain D
        # land on rows >= D*J and are ignored by marking.
        act4 = jnp.repeat(active, 4)
        send4 = jnp.repeat(active & (eff > 1.0), 4)
        done4 = jnp.repeat(newly_done, 4)
        wire4 = jnp.repeat(iwire, 4)
        psn4 = jnp.repeat(ipsn + pkts, 4)
        pkts4 = jnp.repeat(pkts, 4)
        sm4 = sm.reshape(-1)

        cnt = state.s_cnt.at[djf].add(jnp.where(act4, pkts4, 0.0))
        cntop = state.s_cntop.at[djf].add(
            jnp.where(act4 & (wire4 > sm4), pkts4, 0.0))
        # optimistic advancement on LAST events, then lazy correction
        cand = jnp.zeros(DJ, jnp.int32).at[djf].max(
            jnp.where(done4, wire4 + 1, 0))
        cand = jnp.maximum(state.s_stepmin, cand)
        min_act = jnp.full(DJ, BIG).at[djf].min(
            jnp.where(act4 & ~done4, wire4, BIG))
        stepmin = jnp.where(min_act < BIG, jnp.minimum(cand, min_act), cand)
        psnwin = state.s_psnwin.at[djf].max(
            jnp.where(send4 & ~done4 & (wire4 == stepmin[djf]), psn4, 0.0))

        sym_epoch = (tick % cfg.sym_win_ticks) == (cfg.sym_win_ticks - 1)
        have = cnt > jnp.float32(cfg.sym.n_sample)
        exceed = cntop >= jnp.float32(cfg.sym.tau) * cnt
        alpha_new = jnp.clip(state.s_alpha + jnp.where(exceed, 1.0, -1.0) * have,
                             1.0, jnp.float32(cfg.sym.alpha_max))
        s_alpha = jnp.where(sym_epoch, alpha_new, state.s_alpha)
        s_cnt = jnp.where(sym_epoch, 0.0, cnt)
        s_cntop = jnp.where(sym_epoch, 0.0, cntop)
        s_psnwin = jnp.where(sym_epoch, 0.0, psnwin)

        # ------------------------------------------------ 6. DCQCN epoch
        cc_epoch = (tick % cfg.cc_epoch_ticks) == (cfg.cc_epoch_ticks - 1)

        def cc_update(args):
            rate, target, alpha_cc, stage, lam, key = args
            key, sub = jax.random.split(key)
            u = jax.random.uniform(sub, (F, W))
            cut = (u < 1.0 - jnp.exp(-lam)) & (step_of >= 0)
            r_c = jnp.maximum(rate * (1.0 - alpha_cc / 2.0), cfg.cc_min_rate)
            # DCQCN: the recovery target snapshots the current rate on the
            # *first* cut of a congestion event only; consecutive cuts
            # (stage==0) keep the previous target so fast recovery can bounce
            # back to the pre-congestion operating point.
            t_c = jnp.where(stage > 0, rate, target)
            a_c = (1.0 - cfg.cc_g) * alpha_cc + cfg.cc_g
            a_n = (1.0 - cfg.cc_g) * alpha_cc
            stage_n = stage + 1
            tgt_inc = jnp.where(stage_n > cfg.cc_fr_stages,
                                jnp.where(stage_n > 2 * cfg.cc_fr_stages,
                                          cfg.cc_rhai, cfg.cc_rai), 0.0)
            t_n = jnp.minimum(target + tgt_inc, line_rate[:, None])
            r_n = jnp.minimum((rate + t_n) / 2.0, line_rate[:, None])
            return (jnp.where(cut, r_c, r_n), jnp.where(cut, t_c, t_n),
                    jnp.where(cut, a_c, a_n), jnp.where(cut, 0, stage_n),
                    jnp.zeros_like(lam), key)

        rate, target, alpha_cc, stage, lam, key = jax.lax.cond(
            cc_epoch, cc_update, lambda a: a,
            (rate, target, alpha_cc, stage, lam, state.key))

        # ------------------------------------------------ 7. segments / jobs
        seg_phase = state.seg_idx % wl.n_phases
        participating = wl.phase == seg_phase[wl.job]
        c_end = (state.seg_idx[wl.job] // nph_f + 1) * wl.sps
        flow_done = ((~participating) | (done_upto >= c_end)).astype(jnp.int32)
        seg_done = jnp.ones(J, jnp.int32).at[wl.job].min(flow_done) > 0
        adv = seg_done & (state.seg_idx < wl.n_segs) & (tick >= state.seg_ready)
        seg_idx = state.seg_idx + adv.astype(jnp.int32)
        new_phase0 = (seg_idx % wl.n_phases) == 0
        seg_ready = jnp.where(adv,
                              tick + jnp.where(new_phase0, wl.gap_ticks, 0),
                              state.seg_ready)
        job_finish = jnp.where((seg_idx >= wl.n_segs) &
                               (state.job_finish == I32MAX),
                               tick, state.job_finish)

        # ------------------------------------------------ metrics
        min_wire = jnp.full(J, BIG).at[inst_job].min(jnp.where(active, iwire, BIG))
        max_wire = jnp.full(J, -1).at[inst_job].max(jnp.where(active, iwire, -1))
        done_min = jnp.full(J, BIG).at[wl.job].min(done_upto)
        tput = jnp.zeros(J).at[inst_job].add(eff)
        sample = (min_wire, max_wire, done_min, tput, q[:L].max(), s_alpha.max())

        new_state = _State(
            next_step=next_step, done_upto=done_upto, finish=finish,
            step_of=step_of, sent=sent, rate=rate, target=target,
            alpha_cc=alpha_cc, stage=stage, lam=lam, q=q,
            s_stepmin=stepmin, s_psnwin=s_psnwin, s_alpha=s_alpha,
            s_cnt=s_cnt, s_cntop=s_cntop,
            seg_idx=seg_idx, seg_ready=seg_ready, job_finish=job_finish,
            key=key,
        )
        return new_state, sample

    R = cfg.record_every
    n_rec = cfg.n_ticks // R

    def rec_body(state, r):
        ticks = r * R + jnp.arange(R)
        state, samples = jax.lax.scan(tick_fn, state, ticks)
        return state, jax.tree.map(lambda x: x[-1], samples)

    state, samples = jax.lax.scan(rec_body, state0, jnp.arange(n_rec))
    min_w, max_w, done_min, tput, qmax, alph = samples
    return SimResult(
        finish_ticks=state.finish,
        job_finish_ticks=state.job_finish,
        ts_min_wire=min_w, ts_max_wire=max_w, ts_done_min=done_min,
        ts_throughput=tput, ts_qmax=qmax, ts_alpha_max=alph,
    )


def _resolve_routing(cfg: SimParams, routing: str) -> tuple[SimParams, str]:
    """Routing modes: 'ecmp' (per-step re-hash, default), 'ecmp_flow'
    (persistent per-flow paths), 'balanced' (static round-robin)."""
    if routing == "ecmp":
        return cfg._replace(per_step_ecmp=True), "ecmp"
    if routing == "ecmp_flow":
        return cfg._replace(per_step_ecmp=False), "ecmp"
    if routing == "balanced":
        return cfg._replace(per_step_ecmp=False), "balanced"
    raise ValueError(routing)


def simulate(topo: Topology, wl: Workload, cfg: SimParams,
             routing: str = "ecmp", seed: int = 0,
             bg_base: np.ndarray | None = None,
             bg_amp: np.ndarray | None = None,
             bg_period: float = 1e-3, bg_duty: float = 0.0) -> SimResult:
    """Single-run entry point."""
    cfg, mode = _resolve_routing(cfg, routing)
    st = build_static(topo, wl, mode, seed, bg_base, bg_amp, bg_period,
                      bg_duty, cfg.dt)
    return simulate_core(st, wl_arrays(wl, cfg.dt), cfg, jax.random.PRNGKey(seed))


def simulate_seeds(topo: Topology, wl: Workload, cfg: SimParams,
                   routing: str, seeds: list[int], **bg) -> SimResult:
    """vmap over seeds: both the ECMP path draw and the DCQCN coin flips vary."""
    cfg, mode = _resolve_routing(cfg, routing)
    statics = [build_static(topo, wl, mode, s, dt=cfg.dt, **bg) for s in seeds]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *statics)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    wla = wl_arrays(wl, cfg.dt)
    fn = jax.vmap(lambda st, k: simulate_core(st, wla, cfg, k))
    return fn(stacked, keys)
