"""Fluid-flow network simulator for ring-style collectives (our
Astra-Sim + NS-3), built as a staged engine over a generic link table.

One `lax.scan` over fixed ticks of `dt` seconds.  All state is arrays, so
the whole simulation jits and vmaps over seeds/parameters.  The per-tick
body is not monolithic: it is composed from the individually-testable stage
functions in :mod:`repro.core.netsim.stages` (start gating, route selection,
bandwidth sharing, queues/RED, Symphony marking, DCQCN rate control,
segment/job progress, metrics) — `simulate_core` only assembles them into
the scan and handles recording.

Configuration is split along the jit boundary (:mod:`.params`):

* :class:`SimStructure` — static shapes / compile-time choices (`n_ticks`,
  `window`, `record_every`, `share_policy`, `deploy`, `per_step_ecmp`,
  `dt`, `mtu`, and the tick `backend`: `"xla"` staged ops vs `"pallas"`
  fused kernel, see :mod:`repro.kernels.netsim_tick`).  A jit static
  argument; changing a field recompiles.
* :class:`RuntimeKnobs` — every numeric knob (RED, DCQCN, Symphony, the
  `sym_on` / `pq_on` 0/1 gates) as traced f32/i32 leaves.  Changing values
  never recompiles, and grids of knobs vmap through ONE compilation.
* :class:`SimParams` — the backwards-compatible flat facade; `simulate`,
  `simulate_seeds` and `simulate_core` still accept it and split it
  internally, so existing callers keep working unchanged.

Entry points
------------
* :func:`simulate`        — one (params, seed) point.
* :func:`simulate_seeds`  — vmap over seeds (path draws + CC coin flips).
* :func:`simulate_grid`   — the batched grid executor: one compile,
  vmap over knob points x seeds, chunked along the knob axis to bound
  memory.  Result arrays gain leading ``[K, S]`` axes.

Multi-device dispatch
---------------------
``simulate_grid(..., devices=..., mesh=...)`` shards the flattened
``K*S`` lane axis across a 1-D device mesh via ``shard_map`` (the
jax-0.4.37 compat spelling in :mod:`repro.compat`): every device runs
``lanes/D`` independent simulations of the SAME compiled program, so the
one-compile contract (``core_trace_count``) is unchanged.  Lane counts
that don't divide the device count are padded by repeating the last lane
and the padding is masked off the result.  ``devices="auto"`` uses all
local devices; ``chunk_knobs`` bounds the knob points resident *per
device*, so the memory bound composes with sharding.

Entities
--------
flow slot   f in [0, F): persistent (ring, member) sender->successor relation
instance    (f, w): one in-flight step-send of slot f. Steps pipeline (a node
            may start step s once it *received* s-1), so several instances of
            a slot can be concurrently active — this is the step-overlap
            phenomenon the paper studies (Fig. 1e). W = cfg.window slots,
            keyed by s % W.
link        rows of the Topology table + one trailing "null" link with
            infinite capacity (padding for short routes).

Generality
----------
* Topology is any :class:`~repro.core.netsim.topology.Topology` (2-tier
  leaf-spine, 3-tier multi-pod fat-tree, ...): routes are variable-hop
  ``[F, H]`` rows; per-step ECMP re-hashes over the per-flow candidate-path
  table ``[F, P, H]`` instead of assuming one switch tier.
* Bandwidth sharing is pluggable (``share_policy``): ``proportional``
  (default), ``pq`` strict 2-class priority, ``wfq`` weighted-fair across
  jobs (weights via ``build_static(job_weight=...)``), or ``drr`` deficit
  round-robin; the traced ``pq_on`` gate overrides to strict priority at
  runtime.
* Symphony's deployment tier is configurable (``deploy``): ``"tor"``
  (ToR-only, the paper's §5 default), ``"all"`` (every switch),
  ``"spine"`` (spine/core only).

Time is kept in integer ticks (i32) so float32 never loses precision.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

from .params import (RuntimeKnobs, SimParams, SimState, SimStructure,
                     grid_from_params, merge_params, stack_knobs)
from .stages import (BIG, I32MAX, WIRE_SEG, EngineState, WLArrays,  # noqa: F401
                     BACKENDS, SHARE_POLICIES, engine_tick,
                     init_state as engine_init_state,
                     make_ctx, resolve_backend, resolve_share_policy)
from .topology import LEVEL_SPINE, LEVEL_TOR, Topology
from .workload import (Workload, balanced_choice, ecmp_choice, path_table_for,
                       routes_for)

__all__ = [
    "SimParams", "SimStructure", "RuntimeKnobs", "SimResult", "SimState",
    "Static", "WindowSamples",
    "simulate", "simulate_seeds", "simulate_grid", "simulate_core",
    "init_state", "run_window",
    "build_static", "link_domains", "grid_from_params", "stack_knobs",
    "core_trace_count", "resolve_grid_mesh", "GRID_AXIS",
]

# name of the lane axis on the 1-D grid-dispatch mesh
GRID_AXIS = "lanes"


class SimResult(NamedTuple):
    finish_ticks: jax.Array        # [F] completion tick per flow slot (I32MAX if not)
    job_finish_ticks: jax.Array    # [J]
    # sampled series, every record_every ticks:
    ts_min_wire: jax.Array         # [T, J] oldest active wire step (BIG if none)
    ts_max_wire: jax.Array         # [T, J] newest active wire step (-1 if none)
    ts_done_min: jax.Array         # [T, J] min completed local steps over flows
    ts_throughput: jax.Array       # [T, J] delivered bytes/s summed over job
    ts_qmax: jax.Array             # [T]    max queue depth (bytes)
    ts_alpha_max: jax.Array        # [T]    max Symphony alpha over ports
    # batched entry points prepend leading axes: [S, ...] for
    # simulate_seeds, [K, S, ...] for simulate_grid.


class WindowSamples(NamedTuple):
    """The sampled series of one :func:`run_window` call: the same six
    ``ts_*`` series as :class:`SimResult`, but covering only that window's
    ``n_ticks // record_every`` record periods.  Concatenating the windows
    of a split run reproduces the one-shot series exactly."""
    ts_min_wire: jax.Array         # [T, J]
    ts_max_wire: jax.Array         # [T, J]
    ts_done_min: jax.Array         # [T, J]
    ts_throughput: jax.Array       # [T, J]
    ts_qmax: jax.Array             # [T]
    ts_alpha_max: jax.Array        # [T]


class Static(NamedTuple):
    """Per-run device arrays (vmap over leading axis for multi-seed)."""
    routes: jax.Array        # [F, H] static per-flow paths (null-link padded)
    path_table: jax.Array    # [F, P, H] ECMP candidate paths per flow
    n_paths: jax.Array       # [F] candidate fan-out (hash applied modulo)
    cap: jax.Array           # [L+1] bytes/s
    link_dom: jax.Array      # [L+1] Symphony domain (switch) id; D = no Symphony
    dom_pad: jax.Array       # [D+1] zeros; carries the static domain count
    bg_base: jax.Array       # [L+1] bytes/s constant background load
    bg_amp: jax.Array        # [L+1] square-wave background amplitude
    bg_period_ticks: jax.Array  # i32 scalar
    bg_duty: jax.Array          # f32 scalar in [0,1]
    job_weight: jax.Array    # [J] weighted-fair share weights (wfq policy)
    seed: jax.Array          # i32 hash salt


def link_domains(topo: Topology, deploy: str = "tor"
                 ) -> tuple[np.ndarray, int]:
    """Map each link to its Symphony domain (the switch owning its egress
    port), honoring the deployment tier:

    * ``"tor"``   — ToR/edge switches only (paper §5 "Practical deployment")
    * ``"all"``   — every switch tier
    * ``"spine"`` — spine/aggregation and core switches only

    Returns ``(dom [L+1], D)`` where links of non-deployed switches (and
    host NICs, and the null link) map to the null domain ``D``.
    """
    lv = topo.switch_level
    if deploy == "tor":
        sel = lv == LEVEL_TOR
    elif deploy == "all":
        sel = lv >= LEVEL_TOR
    elif deploy == "spine":
        sel = lv >= LEVEL_SPINE
    else:
        raise ValueError(f"unknown deploy tier {deploy!r}")
    sw_ids = np.nonzero(sel)[0]
    D = int(sw_ids.shape[0])
    compact = np.full(topo.n_switches, -1, np.int32)
    compact[sw_ids] = np.arange(D, dtype=np.int32)
    dom = np.full(topo.n_links + 1, D, np.int32)
    owned = topo.link_switch >= 0
    mapped = compact[topo.link_switch[owned]]
    dom[:topo.n_links][owned] = np.where(mapped >= 0, mapped, D)
    return dom, D


def build_static(topo: Topology, wl: Workload, routing: str, seed: int,
                 bg_base: np.ndarray | None = None,
                 bg_amp: np.ndarray | None = None,
                 bg_period: float = 1e-3, bg_duty: float = 0.0,
                 dt: float = 10e-6, deploy: str = "tor",
                 job_weight: np.ndarray | None = None) -> Static:
    if routing == "ecmp":
        choice = ecmp_choice(topo, wl, seed)
    elif routing == "balanced":
        choice = balanced_choice(topo, wl)
    else:
        raise ValueError(routing)
    routes = routes_for(topo, wl, choice)
    paths, n_paths = path_table_for(topo, wl)
    dom, D = link_domains(topo, deploy)
    zb = np.zeros(topo.n_links + 1)
    return Static(
        routes=jnp.asarray(routes, jnp.int32),
        path_table=jnp.asarray(paths, jnp.int32),
        n_paths=jnp.asarray(n_paths, jnp.int32),
        cap=jnp.asarray(np.concatenate([topo.link_cap, [1e30]]), jnp.float32),
        link_dom=jnp.asarray(dom),
        dom_pad=jnp.zeros(D + 1, jnp.float32),
        bg_base=jnp.asarray(zb if bg_base is None else np.append(bg_base, 0.0),
                            jnp.float32),
        bg_amp=jnp.asarray(zb if bg_amp is None else np.append(bg_amp, 0.0),
                           jnp.float32),
        bg_period_ticks=jnp.asarray(max(1, round(bg_period / dt)), jnp.int32),
        bg_duty=jnp.asarray(bg_duty, jnp.float32),
        job_weight=jnp.asarray(
            np.ones(wl.n_jobs) if job_weight is None else job_weight,
            jnp.float32),
        seed=jnp.asarray(seed, jnp.int32),
    )


def wl_arrays(wl: Workload, dt: float) -> WLArrays:
    return WLArrays(
        src=jnp.asarray(wl.src), dst=jnp.asarray(wl.dst),
        pred=jnp.asarray(wl.pred), job=jnp.asarray(wl.job),
        phase=jnp.asarray(wl.phase), sps=jnp.asarray(wl.steps_per_seg),
        pass_steps=jnp.asarray(wl.pass_steps),
        total_steps=jnp.asarray(wl.total_steps()),
        n_phases=jnp.asarray(wl.n_phases),
        n_segs=jnp.asarray(wl.n_passes * wl.n_phases),
        chunk_sched=jnp.asarray(wl.chunk_sched, jnp.float32),
        gap_ticks=jnp.asarray(np.round(wl.compute_gap / dt), jnp.int32),
        start_ticks=jnp.asarray(np.round(wl.start_time / dt), jnp.int32),
        step_offset=jnp.asarray(wl.step_offset),
        fstart_ticks=jnp.asarray(np.round(wl.flow_start / dt), jnp.int32),
        trig_job=jnp.asarray(wl.trig_job, jnp.int32),
        trig_seg=jnp.asarray(wl.trig_seg, jnp.int32),
        trig_delay_ticks=jnp.asarray(np.round(wl.trig_delay / dt), jnp.int32),
    )


# ------------------------------------------------------------------- core
_TRACES = {"core": 0}


def core_trace_count() -> int:
    """How many times the engine body has been traced (== compiled) in
    this process.  The grid executor's contract — and the regression test
    / `netsim_perf` check — is that an entire knob grid adds exactly 1."""
    return _TRACES["core"]


def _window_body(ctx, cfg, sim: SimState, n_ticks: int):
    """Advance the engine ``n_ticks`` ticks from ``sim``, sampling every
    ``record_every`` ticks.  This is the ONE windowed engine body: the
    closed-form `_core_impl` runs it once from tick 0 for the whole
    horizon, and `run_window` re-enters it from any checkpointed
    :class:`~repro.core.netsim.params.SimState` — both through the same
    record-period scan, so a split run replays the identical per-tick
    program (tick indices are re-based on the traced ``sim.tick`` cursor,
    which only ever feeds integer gates, never float operands).

    Executed once per trace, so it doubles as the compile counter."""
    _TRACES["core"] += 1

    def tick_fn(state, tick):
        return engine_tick(ctx, cfg, state, tick)

    R = cfg.record_every
    n_rec = n_ticks // R
    tick0 = sim.tick

    w = int(getattr(cfg, "tick_window", 1) or 1)
    if w < 1:
        raise ValueError(f"tick_window must be >= 1, got {w}")
    if w > 1 and resolve_backend(cfg) != "pallas":
        raise ValueError(
            f"tick_window={w} > 1 requires the fused pallas backend "
            f"(got backend={cfg.backend!r}, share_policy="
            f"{cfg.share_policy!r}; wfq/drr fall back to the staged XLA "
            "path, which has no multi-tick window kernel)")
    # A window never spans a record boundary: the sample contract is "the
    # last tick of each record period", so windows chunk each period into
    # R // w full windows plus one R % w remainder window.
    w = min(w, R)

    if w > 1:
        # The window kernel donates the carried engine state: each pallas
        # call aliases its N_STATE state inputs to the state outputs
        # (window.py input_output_aliases), so this record-period scan
        # updates the state buffers in place — no extra state copy per
        # window on the pallas path.
        from ...kernels.netsim_tick.ops import engine_window_fused
        n_full, rem = divmod(R, w)

        def rec_body(state, r):
            base = tick0 + r * R
            sample = None
            if n_full:
                def win(state, j):
                    return engine_window_fused(ctx, cfg, state,
                                               base + j * w, w)
                state, samples = jax.lax.scan(win, state,
                                              jnp.arange(n_full))
                sample = jax.tree.map(lambda x: x[-1], samples)
            if rem:
                state, sample = engine_window_fused(ctx, cfg, state,
                                                    base + n_full * w, rem)
            return state, sample
    else:
        def rec_body(state, r):
            ticks = tick0 + r * R + jnp.arange(R)
            state, samples = jax.lax.scan(tick_fn, state, ticks)
            return state, jax.tree.map(lambda x: x[-1], samples)

    state, samples = jax.lax.scan(rec_body, sim.engine, jnp.arange(n_rec))
    sim = SimState(tick=tick0 + jnp.int32(n_rec * R), engine=state)
    return sim, samples


def _core_impl(st: Static, wl: WLArrays, struct: SimStructure,
               knobs: RuntimeKnobs, key: jax.Array) -> SimResult:
    """The closed-form engine body: init + one full-horizon window.
    Shared by the single-run and grid jit wrappers."""
    cfg = merge_params(struct, knobs)
    resolve_share_policy(cfg)        # fail fast on unknown policy names
    ctx = make_ctx(st, wl, cfg.window)
    sim0 = SimState(tick=jnp.int32(0), engine=engine_init_state(ctx, key))
    sim, samples = _window_body(ctx, cfg, sim0, cfg.n_ticks)
    min_w, max_w, done_min, tput, qmax, alph = samples
    return SimResult(
        finish_ticks=sim.engine.finish,
        job_finish_ticks=sim.engine.job_finish,
        ts_min_wire=min_w, ts_max_wire=max_w, ts_done_min=done_min,
        ts_throughput=tput, ts_qmax=qmax, ts_alpha_max=alph,
    )


def _flatten_lanes(st_stack: Static, knobs_stack: RuntimeKnobs,
                   keys: jax.Array):
    """Flatten the (K knobs, S seeds) cross product to a SINGLE batch axis
    of ``K*S`` lanes (lane ``i = k*S + s``, row-major) rather than nested
    vmaps: one-level batching keeps XLA's scatter-add accumulation order
    per lane identical to the unbatched program, so grid slices are
    bitwise-equal to per-point ``simulate`` calls (nested vmaps reorder
    the adds by ~1 ulp)."""
    K = int(jax.tree.leaves(knobs_stack)[0].shape[0])
    S = int(keys.shape[0])
    sts = jax.tree.map(
        lambda x: jnp.broadcast_to(
            x[None], (K,) + x.shape).reshape((K * S,) + x.shape[1:]),
        st_stack)
    kns = jax.tree.map(lambda x: jnp.repeat(x, S, axis=0), knobs_stack)
    kys = jnp.broadcast_to(keys[None], (K,) + keys.shape).reshape(
        (K * S,) + keys.shape[1:])
    return sts, kns, kys


def _lanes_impl(sts: Static, wl: WLArrays, struct: SimStructure,
                kns: RuntimeKnobs, kys: jax.Array) -> SimResult:
    """vmap the engine body over a flat lane axis (the shared inner core
    of the single-device and sharded grid programs)."""
    return jax.vmap(lambda st, kn, k: _core_impl(st, wl, struct, kn, k))(
        sts, kns, kys)


def _grid_impl(st_stack: Static, wl: WLArrays, struct: SimStructure,
               knobs_stack: RuntimeKnobs, keys: jax.Array) -> SimResult:
    """Single-device grid program: vmap knob points x seeds through one
    trace of the engine body; outputs reshaped back to leading ``[K, S]``.
    """
    K = int(jax.tree.leaves(knobs_stack)[0].shape[0])
    S = int(keys.shape[0])
    sts, kns, kys = _flatten_lanes(st_stack, knobs_stack, keys)
    flat = _lanes_impl(sts, wl, struct, kns, kys)
    return jax.tree.map(
        lambda x: x.reshape((K, S) + x.shape[1:]), flat)


_grid_core = functools.partial(jax.jit, static_argnames=("struct",))(
    _grid_impl)


def _sharded_grid_impl(st_stack: Static, wl: WLArrays,
                       knobs_stack: RuntimeKnobs, keys: jax.Array, *,
                       struct: SimStructure, mesh) -> SimResult:
    """Sharded grid program: split the flattened ``K*S`` lane axis across
    the 1-D device mesh via ``shard_map``.

    Lanes are independent simulations, so the body needs no collectives —
    each device vmaps the SAME engine trace over its ``lanes/D`` slice
    (``core_trace_count`` still advances by exactly 1 per grid).  When
    ``K*S`` does not divide the device count D, the lane axis is padded
    by repeating the last lane ("edge" padding keeps the padded work
    identical to real work, no NaN/denormal hazards) and the padding is
    masked off the output before the ``[K, S]`` reshape.
    """
    K = int(jax.tree.leaves(knobs_stack)[0].shape[0])
    S = int(keys.shape[0])
    sts, kns, kys = _flatten_lanes(st_stack, knobs_stack, keys)
    D = int(mesh.devices.size)
    pad = (-(K * S)) % D

    def edge_pad(x):
        return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1), mode="edge")

    if pad:
        sts, kns, kys = jax.tree.map(edge_pad, (sts, kns, kys))
    axis = mesh.axis_names[0]
    lane = jax.sharding.PartitionSpec(axis)
    rep = jax.sharding.PartitionSpec()
    fn = compat.shard_map(
        lambda a, b, c, d: _lanes_impl(a, b, struct, c, d),
        mesh=mesh, in_specs=(lane, rep, lane, lane), out_specs=lane)
    flat = fn(sts, wl, kns, kys)
    return jax.tree.map(
        lambda x: x[:K * S].reshape((K, S) + x.shape[1:]), flat)


_sharded_core = functools.partial(jax.jit, static_argnames=("struct", "mesh"))(
    _sharded_grid_impl)


def resolve_grid_mesh(devices=None, mesh=None):
    """Resolve ``simulate_grid``'s ``devices=`` / ``mesh=`` knobs into a
    1-D lane mesh, or ``None`` for plain single-device dispatch.

    * ``mesh=Mesh``        — use as-is (must be 1-D);
    * ``devices=None``     — single device (the bitwise-stable default);
    * ``devices="auto"``   — all local devices;
    * ``devices=int``      — the first N local devices;
    * ``devices=sequence`` — exactly those ``jax.Device`` objects.

    A resolved mesh of one device is normalized to ``None``: single-lane
    meshes add dispatch overhead without buying parallelism, and the
    unsharded program is the bit-for-bit reference.
    """
    if mesh is not None:
        if devices is not None:
            raise ValueError("pass either devices= or mesh=, not both")
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"grid mesh must be 1-D, got axes {mesh.axis_names}")
        return None if mesh.devices.size == 1 else mesh
    if devices is None:
        return None
    if isinstance(devices, str):
        if devices != "auto":
            raise ValueError(f"devices= accepts 'auto', an int, or a "
                             f"device sequence; got {devices!r}")
        devs = jax.local_devices()
    elif isinstance(devices, int):
        devs = jax.local_devices()
        if not 1 <= devices <= len(devs):
            raise ValueError(
                f"devices={devices} out of range; have {len(devs)} "
                "local devices")
        devs = devs[:devices]
    else:
        devs = list(devices)
        if not devs:
            raise ValueError("empty device sequence")
    if len(devs) == 1:
        return None
    return jax.sharding.Mesh(np.array(devs), (GRID_AXIS,))


def simulate_core(st: Static, wl: WLArrays, cfg, knobs_or_key, key=None
                  ) -> SimResult:
    """Jitted core.  Two call forms:

    * new:    ``simulate_core(st, wl, structure, knobs, key)``
    * legacy: ``simulate_core(st, wl, sim_params, key)`` — the flat
      :class:`SimParams` is split internally; knob values are traced, so
      repeat calls with different knob values reuse one compilation.

    Dispatches through the grid core as a 1x1 grid: every entry point
    runs the SAME compiled program family, which keeps single runs
    bitwise-consistent with grid slices (separately-compiled unbatched
    programs can differ by ~1 ulp through XLA fusion reassociation).
    """
    if isinstance(cfg, SimParams):
        if key is not None:
            raise TypeError("legacy form is simulate_core(st, wl, cfg, key)")
        resolve_share_policy(cfg)    # full static validation (pq_on conflicts)
        struct, knobs = cfg.split()
        key = knobs_or_key
    else:
        struct, knobs = cfg, knobs_or_key
        _check_pq_conflict(struct, knobs.pq_on)
    res = _grid_core(jax.tree.map(lambda x: x[None], st), wl, struct,
                     jax.tree.map(lambda x: x[None], knobs), key[None])
    return jax.tree.map(lambda x: x[0, 0], res)


def _check_pq_conflict(struct: SimStructure, pq_on) -> None:
    """Same conflict rule ``resolve_share_policy`` enforces for static
    configs: the pq_on gate overrides the base policy at runtime, so a
    pq point under a wfq/drr structure would silently run strict
    priority.  Knob values are concrete pre-jit, so this is checkable."""
    if struct.share_policy not in ("proportional", "pq") and \
            bool(np.any(np.asarray(pq_on))):
        raise ValueError(
            f"pq_on=True conflicts with share_policy="
            f"{struct.share_policy!r}; use pq only over a "
            "proportional-base structure")


# ---------------------------------------------- windowed checkpoint / resume
def _window_lanes(sts: Static, wl: WLArrays, kns: RuntimeKnobs,
                  sims: SimState, *, struct: SimStructure,
                  n_ticks: int):
    """vmap the windowed engine body over a flat lane axis — the same
    per-lane program structure as `_lanes_impl`, so windowed lanes stay
    bitwise-consistent with closed-form grid lanes."""
    def one(st, kn, sim):
        cfg = merge_params(struct, kn)
        ctx = make_ctx(st, wl, cfg.window)
        return _window_body(ctx, cfg, sim, n_ticks)

    return jax.vmap(one)(sts, kns, sims)


_window_core = functools.partial(
    jax.jit, static_argnames=("struct", "n_ticks"))(_window_lanes)


def init_state(st: Static, wl: WLArrays, struct: SimStructure,
               key: jax.Array | int = 0) -> SimState:
    """Build the tick-0 :class:`~repro.core.netsim.params.SimState` of a
    simulation: the public checkpoint that :func:`run_window` advances.

    ``key`` seeds the DCQCN coin flips — pass the ``jax.random.PRNGKey``
    you would hand :func:`simulate_core` (an int is promoted for you).
    """
    if struct.share_policy not in SHARE_POLICIES:
        raise ValueError(
            f"unknown share policy {struct.share_policy!r}; "
            f"have {sorted(SHARE_POLICIES)}")
    if not isinstance(key, jax.Array):
        key = jax.random.PRNGKey(int(key))
    ctx = make_ctx(st, wl, struct.window)
    return SimState(tick=jnp.int32(0), engine=engine_init_state(ctx, key))


def run_window(st: Static, wl: WLArrays, struct: SimStructure,
               knobs: RuntimeKnobs, state: SimState, n_ticks: int
               ) -> tuple[SimState, WindowSamples]:
    """Advance a checkpointed simulation by ``n_ticks`` ticks.

    The windowed core of the engine: one ``lax.scan`` chunk, compiled
    once per ``(struct, n_ticks)`` and reused across calls — knob value
    changes between windows never retrace (the PR-2 contract), so an
    online controller can retune :class:`RuntimeKnobs` every window for
    free.  ``n_ticks`` must be a positive multiple of
    ``struct.record_every`` (windows never split a record period, which
    is what makes split-run sample series concatenate exactly).

    Dispatches as a 1-lane vmapped program (like every other entry
    point), so resumed runs are bit-for-bit identical to one-shot
    :func:`simulate` outputs: integer outputs and ``ts_alpha_max``
    match exactly, including under the fused pallas backend with
    ``tick_window``/``blk`` tiling active.

    Returns ``(state', samples)`` where ``samples`` is a
    :class:`WindowSamples` covering this window's record periods.
    """
    _check_pq_conflict(struct, knobs.pq_on)
    if struct.backend not in BACKENDS:
        raise ValueError(
            f"unknown tick backend {struct.backend!r}; have {BACKENDS}")
    if struct.share_policy not in SHARE_POLICIES:
        raise ValueError(
            f"unknown share policy {struct.share_policy!r}; "
            f"have {sorted(SHARE_POLICIES)}")
    R = struct.record_every
    n_ticks = int(n_ticks)
    if n_ticks <= 0 or n_ticks % R:
        raise ValueError(
            f"n_ticks must be a positive multiple of record_every={R} "
            f"(samples are taken on the record grid), got {n_ticks}")
    sim, samples = _window_core(
        jax.tree.map(lambda x: x[None], st), wl,
        jax.tree.map(lambda x: x[None], knobs),
        jax.tree.map(lambda x: x[None], state),
        struct=struct, n_ticks=n_ticks)
    return (jax.tree.map(lambda x: x[0], sim),
            WindowSamples(*(x[0] for x in samples)))


# ------------------------------------------------------------ entry points
def _resolve_routing(cfg, routing: str):
    """Routing modes: 'ecmp' (per-step re-hash, default), 'ecmp_flow'
    (persistent per-flow paths), 'balanced' (static round-robin).
    Works on SimParams and SimStructure alike."""
    if routing == "ecmp":
        return cfg._replace(per_step_ecmp=True), "ecmp"
    if routing == "ecmp_flow":
        return cfg._replace(per_step_ecmp=False), "ecmp"
    if routing == "balanced":
        return cfg._replace(per_step_ecmp=False), "balanced"
    raise ValueError(routing)


def simulate(topo: Topology, wl: Workload, cfg: SimParams,
             routing: str = "ecmp", seed: int = 0,
             bg_base: np.ndarray | None = None,
             bg_amp: np.ndarray | None = None,
             bg_period: float = 1e-3, bg_duty: float = 0.0,
             job_weight: np.ndarray | None = None) -> SimResult:
    """Single-run entry point."""
    cfg, mode = _resolve_routing(cfg, routing)
    st = build_static(topo, wl, mode, seed, bg_base, bg_amp, bg_period,
                      bg_duty, cfg.dt, deploy=cfg.deploy,
                      job_weight=job_weight)
    return simulate_core(st, wl_arrays(wl, cfg.dt), cfg, jax.random.PRNGKey(seed))


def _stacked_statics(topo, wl, mode, seeds, struct, bg_base=None, bg_amp=None,
                     bg_period=1e-3, bg_duty=0.0, job_weight=None):
    statics = [build_static(topo, wl, mode, s, bg_base, bg_amp, bg_period,
                            bg_duty, struct.dt, deploy=struct.deploy,
                            job_weight=job_weight) for s in seeds]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *statics)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    return stacked, keys


def simulate_seeds(topo: Topology, wl: Workload, cfg: SimParams,
                   routing: str, seeds: Sequence[int],
                   devices=None, mesh=None, **bg) -> SimResult:
    """vmap over seeds: both the ECMP path draw and the DCQCN coin flips
    vary.  Result arrays gain a leading ``[S]`` axis.

    Implemented as a 1-point knob grid, so it shares the grid executor's
    compilation cache; ``devices=`` / ``mesh=`` shard the seed lanes
    across devices exactly like grid lanes."""
    resolve_share_policy(cfg)
    struct, knobs = cfg.split()
    res = simulate_grid(topo, wl, struct,
                        jax.tree.map(lambda x: x[None], knobs), seeds,
                        routing=routing, devices=devices, mesh=mesh, **bg)
    return jax.tree.map(lambda x: x[0], res)


def simulate_grid(topo: Topology, wl: Workload, struct: SimStructure,
                  knobs_grid, seeds: Sequence[int] = (0,),
                  routing: str = "ecmp", chunk_knobs: int | None = None,
                  devices=None, mesh=None, **bg) -> SimResult:
    """Batched grid executor: one compile, vmap over knob points x seeds.

    ``knobs_grid`` is a stacked :class:`RuntimeKnobs` pytree (leading axis
    K), or a sequence of per-point ``RuntimeKnobs`` / ``SimParams`` (the
    latter must share ``struct``'s static structure).  Build one from flat
    configs with :func:`grid_from_params`.

    ``devices=`` / ``mesh=`` (see :func:`resolve_grid_mesh`) shard the
    flattened ``K*S`` lane axis across a 1-D device mesh: each device runs
    an equal slice of the lanes through the same single compilation, with
    the lane axis padded (and the padding masked off the result) when the
    lane count doesn't divide the device count.

    The grid is chunked along the knob axis (``chunk_knobs`` points per
    device, default: the whole grid) to bound memory; under a D-device
    mesh one dispatch covers ``chunk_knobs * D`` knob points, so the
    per-device memory bound is preserved.  The last partial chunk is
    padded by repeating the final point, so every chunk has the same
    shape and the engine still traces exactly once.

    Returns a :class:`SimResult` whose arrays carry leading ``[K, S]``
    axes (knob point x seed).
    """
    if (isinstance(knobs_grid, (list, tuple))
            and not isinstance(knobs_grid, RuntimeKnobs)):
        pts = [p.knobs() if isinstance(p, SimParams) else p
               for p in knobs_grid]
        for p in knobs_grid:
            if isinstance(p, SimParams) and p.structure() != struct:
                raise ValueError(
                    "grid point differs from struct in static fields; "
                    "use grid_from_params to derive a common structure")
        knobs_grid = stack_knobs(pts)
    if struct.share_policy not in SHARE_POLICIES:
        raise ValueError(
            f"unknown share policy {struct.share_policy!r}; "
            f"have {sorted(SHARE_POLICIES)}")
    if struct.backend not in BACKENDS:
        raise ValueError(
            f"unknown tick backend {struct.backend!r}; have {BACKENDS}")
    _check_pq_conflict(struct, knobs_grid.pq_on)
    mesh = resolve_grid_mesh(devices, mesh)
    struct, mode = _resolve_routing(struct, routing)
    stacked, keys = _stacked_statics(topo, wl, mode, seeds, struct, **bg)
    wla = wl_arrays(wl, struct.dt)

    K = int(jax.tree.leaves(knobs_grid)[0].shape[0])
    D = 1 if mesh is None else int(mesh.devices.size)
    # chunk_knobs bounds the knob points resident PER DEVICE, so a
    # D-device dispatch covers chunk_knobs * D points at a time.
    per_dev = K if chunk_knobs is None else max(1, min(int(chunk_knobs), K))
    chunk = min(K, per_dev * D)
    pad = (-K) % chunk
    if pad:
        # repeat the final point so the last partial chunk has the same
        # shape as the others (one trace); its padded rows are sliced off
        # the concatenated result below, never observed by callers.
        knobs_grid = jax.tree.map(
            lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]),
            knobs_grid)
    outs = []
    for i in range(0, K + pad, chunk):
        kn = jax.tree.map(lambda x: x[i:i + chunk], knobs_grid)
        if mesh is None:
            outs.append(_grid_core(stacked, wla, struct, kn, keys))
        else:
            outs.append(_sharded_core(stacked, wla, kn, keys,
                                      struct=struct, mesh=mesh))
    if len(outs) == 1:
        return outs[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0)[:K], *outs)
