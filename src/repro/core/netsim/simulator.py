"""Fluid-flow network simulator for ring-style collectives (our
Astra-Sim + NS-3), built as a staged engine over a generic link table.

One `lax.scan` over fixed ticks of `dt` seconds.  All state is arrays, so
the whole simulation jits and vmaps over seeds/parameters.  The per-tick
body is not monolithic: it is composed from the individually-testable stage
functions in :mod:`repro.core.netsim.stages` (start gating, route selection,
bandwidth sharing, queues/RED, Symphony marking, DCQCN rate control,
segment/job progress, metrics) — `simulate_core` only assembles them into
the scan and handles recording.

Entities
--------
flow slot   f in [0, F): persistent (ring, member) sender->successor relation
instance    (f, w): one in-flight step-send of slot f. Steps pipeline (a node
            may start step s once it *received* s-1), so several instances of
            a slot can be concurrently active — this is the step-overlap
            phenomenon the paper studies (Fig. 1e). W = cfg.window slots,
            keyed by s % W.
link        rows of the Topology table + one trailing "null" link with
            infinite capacity (padding for short routes).

Generality
----------
* Topology is any :class:`~repro.core.netsim.topology.Topology` (2-tier
  leaf-spine, 3-tier multi-pod fat-tree, ...): routes are variable-hop
  ``[F, H]`` rows; per-step ECMP re-hashes over the per-flow candidate-path
  table ``[F, P, H]`` instead of assuming one switch tier.
* Bandwidth sharing is pluggable (``SimParams.share_policy``):
  ``proportional`` (default), ``pq`` strict 2-class priority, or ``wfq``
  weighted-fair across jobs (weights via ``build_static(job_weight=...)``).
* Symphony's deployment tier is configurable (``SimParams.deploy``):
  ``"tor"`` (ToR-only, the paper's §5 default), ``"all"`` (every switch),
  ``"spine"`` (spine/core only).

Time is kept in integer ticks (i32) so float32 never loses precision.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..symphony import SymphonyParams
from .stages import (BIG, I32MAX, WIRE_SEG, EngineState, WLArrays,  # noqa: F401
                     engine_tick, init_state, make_ctx, resolve_share_policy)
from .topology import LEVEL_SPINE, LEVEL_TOR, Topology
from .workload import (Workload, balanced_choice, ecmp_choice, path_table_for,
                       routes_for)


class SimParams(NamedTuple):
    dt: float = 10e-6
    n_ticks: int = 20_000
    window: int = 48               # max concurrent steps per slot (W)
    mtu: float = 1000.0            # bytes per "packet" (psn unit)
    record_every: int = 20         # metric sampling period (ticks)
    # RED / ECN (bytes)
    red_kmin: float = 50e3
    red_kmax: float = 100e3
    red_pmax: float = 0.2
    # DCQCN-style rate control
    cc_epoch_ticks: int = 5        # 50 us control epoch
    cc_g: float = 1.0 / 16.0
    cc_rai: float = 5e6            # additive increase (bytes/s) = 40 Mb/s
    cc_rhai: float = 25e6          # hyper increase
    cc_fr_stages: int = 5
    cc_min_rate: float = 1.25e5    # 1 Mb/s floor (paper §5 "soft limit")
    # Symphony
    sym_on: bool = False
    sym: SymphonyParams = SymphonyParams()
    sym_win_ticks: int = 10        # T_win = 100 us
    sym_start_tick: int = 0        # late-start experiments (Fig. 4)
    deploy: str = "tor"            # Symphony tier: "tor" | "all" | "spine"
    # Alternatives / knobs
    pq_on: bool = False            # strict-priority for lagging flows (Fig. 5)
    share_policy: str = "proportional"  # "proportional" | "pq" | "wfq"
    per_step_ecmp: bool = True     # re-hash the 5-tuple every step (§4.7: the
                                   # step index lives in the UDP sport, so each
                                   # step is a distinct flow to ECMP)


class SimResult(NamedTuple):
    finish_ticks: jax.Array        # [F] completion tick per flow slot (I32MAX if not)
    job_finish_ticks: jax.Array    # [J]
    # sampled series, every record_every ticks:
    ts_min_wire: jax.Array         # [T, J] oldest active wire step (BIG if none)
    ts_max_wire: jax.Array         # [T, J] newest active wire step (-1 if none)
    ts_done_min: jax.Array         # [T, J] min completed local steps over flows
    ts_throughput: jax.Array       # [T, J] delivered bytes/s summed over job
    ts_qmax: jax.Array             # [T]    max queue depth (bytes)
    ts_alpha_max: jax.Array        # [T]    max Symphony alpha over ports


class Static(NamedTuple):
    """Per-run device arrays (vmap over leading axis for multi-seed)."""
    routes: jax.Array        # [F, H] static per-flow paths (null-link padded)
    path_table: jax.Array    # [F, P, H] ECMP candidate paths per flow
    n_paths: jax.Array       # [F] candidate fan-out (hash applied modulo)
    cap: jax.Array           # [L+1] bytes/s
    link_dom: jax.Array      # [L+1] Symphony domain (switch) id; D = no Symphony
    dom_pad: jax.Array       # [D+1] zeros; carries the static domain count
    bg_base: jax.Array       # [L+1] bytes/s constant background load
    bg_amp: jax.Array        # [L+1] square-wave background amplitude
    bg_period_ticks: jax.Array  # i32 scalar
    bg_duty: jax.Array          # f32 scalar in [0,1]
    job_weight: jax.Array    # [J] weighted-fair share weights (wfq policy)
    seed: jax.Array          # i32 hash salt


def link_domains(topo: Topology, deploy: str = "tor"
                 ) -> tuple[np.ndarray, int]:
    """Map each link to its Symphony domain (the switch owning its egress
    port), honoring the deployment tier:

    * ``"tor"``   — ToR/edge switches only (paper §5 "Practical deployment")
    * ``"all"``   — every switch tier
    * ``"spine"`` — spine/aggregation and core switches only

    Returns ``(dom [L+1], D)`` where links of non-deployed switches (and
    host NICs, and the null link) map to the null domain ``D``.
    """
    lv = topo.switch_level
    if deploy == "tor":
        sel = lv == LEVEL_TOR
    elif deploy == "all":
        sel = lv >= LEVEL_TOR
    elif deploy == "spine":
        sel = lv >= LEVEL_SPINE
    else:
        raise ValueError(f"unknown deploy tier {deploy!r}")
    sw_ids = np.nonzero(sel)[0]
    D = int(sw_ids.shape[0])
    compact = np.full(topo.n_switches, -1, np.int32)
    compact[sw_ids] = np.arange(D, dtype=np.int32)
    dom = np.full(topo.n_links + 1, D, np.int32)
    owned = topo.link_switch >= 0
    mapped = compact[topo.link_switch[owned]]
    dom[:topo.n_links][owned] = np.where(mapped >= 0, mapped, D)
    return dom, D


def build_static(topo: Topology, wl: Workload, routing: str, seed: int,
                 bg_base: np.ndarray | None = None,
                 bg_amp: np.ndarray | None = None,
                 bg_period: float = 1e-3, bg_duty: float = 0.0,
                 dt: float = 10e-6, deploy: str = "tor",
                 job_weight: np.ndarray | None = None) -> Static:
    if routing == "ecmp":
        choice = ecmp_choice(topo, wl, seed)
    elif routing == "balanced":
        choice = balanced_choice(topo, wl)
    else:
        raise ValueError(routing)
    routes = routes_for(topo, wl, choice)
    paths, n_paths = path_table_for(topo, wl)
    dom, D = link_domains(topo, deploy)
    zb = np.zeros(topo.n_links + 1)
    return Static(
        routes=jnp.asarray(routes, jnp.int32),
        path_table=jnp.asarray(paths, jnp.int32),
        n_paths=jnp.asarray(n_paths, jnp.int32),
        cap=jnp.asarray(np.concatenate([topo.link_cap, [1e30]]), jnp.float32),
        link_dom=jnp.asarray(dom),
        dom_pad=jnp.zeros(D + 1, jnp.float32),
        bg_base=jnp.asarray(zb if bg_base is None else np.append(bg_base, 0.0),
                            jnp.float32),
        bg_amp=jnp.asarray(zb if bg_amp is None else np.append(bg_amp, 0.0),
                           jnp.float32),
        bg_period_ticks=jnp.asarray(max(1, round(bg_period / dt)), jnp.int32),
        bg_duty=jnp.asarray(bg_duty, jnp.float32),
        job_weight=jnp.asarray(
            np.ones(wl.n_jobs) if job_weight is None else job_weight,
            jnp.float32),
        seed=jnp.asarray(seed, jnp.int32),
    )


def wl_arrays(wl: Workload, dt: float) -> WLArrays:
    return WLArrays(
        src=jnp.asarray(wl.src), dst=jnp.asarray(wl.dst),
        pred=jnp.asarray(wl.pred), job=jnp.asarray(wl.job),
        phase=jnp.asarray(wl.phase), sps=jnp.asarray(wl.steps_per_seg),
        pass_steps=jnp.asarray(wl.pass_steps),
        total_steps=jnp.asarray(wl.total_steps()),
        n_phases=jnp.asarray(wl.n_phases),
        n_segs=jnp.asarray(wl.n_passes * wl.n_phases),
        chunk_sched=jnp.asarray(wl.chunk_sched, jnp.float32),
        gap_ticks=jnp.asarray(np.round(wl.compute_gap / dt), jnp.int32),
        start_ticks=jnp.asarray(np.round(wl.start_time / dt), jnp.int32),
        step_offset=jnp.asarray(wl.step_offset),
        fstart_ticks=jnp.asarray(np.round(wl.flow_start / dt), jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def simulate_core(st: Static, wl: WLArrays, cfg: SimParams,
                  key: jax.Array) -> SimResult:
    resolve_share_policy(cfg)        # fail fast on unknown policy names
    ctx = make_ctx(st, wl, cfg.window)
    state0 = init_state(ctx, key)

    def tick_fn(state, tick):
        return engine_tick(ctx, cfg, state, tick)

    R = cfg.record_every
    n_rec = cfg.n_ticks // R

    def rec_body(state, r):
        ticks = r * R + jnp.arange(R)
        state, samples = jax.lax.scan(tick_fn, state, ticks)
        return state, jax.tree.map(lambda x: x[-1], samples)

    state, samples = jax.lax.scan(rec_body, state0, jnp.arange(n_rec))
    min_w, max_w, done_min, tput, qmax, alph = samples
    return SimResult(
        finish_ticks=state.finish,
        job_finish_ticks=state.job_finish,
        ts_min_wire=min_w, ts_max_wire=max_w, ts_done_min=done_min,
        ts_throughput=tput, ts_qmax=qmax, ts_alpha_max=alph,
    )


def _resolve_routing(cfg: SimParams, routing: str) -> tuple[SimParams, str]:
    """Routing modes: 'ecmp' (per-step re-hash, default), 'ecmp_flow'
    (persistent per-flow paths), 'balanced' (static round-robin)."""
    if routing == "ecmp":
        return cfg._replace(per_step_ecmp=True), "ecmp"
    if routing == "ecmp_flow":
        return cfg._replace(per_step_ecmp=False), "ecmp"
    if routing == "balanced":
        return cfg._replace(per_step_ecmp=False), "balanced"
    raise ValueError(routing)


def simulate(topo: Topology, wl: Workload, cfg: SimParams,
             routing: str = "ecmp", seed: int = 0,
             bg_base: np.ndarray | None = None,
             bg_amp: np.ndarray | None = None,
             bg_period: float = 1e-3, bg_duty: float = 0.0,
             job_weight: np.ndarray | None = None) -> SimResult:
    """Single-run entry point."""
    cfg, mode = _resolve_routing(cfg, routing)
    st = build_static(topo, wl, mode, seed, bg_base, bg_amp, bg_period,
                      bg_duty, cfg.dt, deploy=cfg.deploy,
                      job_weight=job_weight)
    return simulate_core(st, wl_arrays(wl, cfg.dt), cfg, jax.random.PRNGKey(seed))


def simulate_seeds(topo: Topology, wl: Workload, cfg: SimParams,
                   routing: str, seeds: list[int], **bg) -> SimResult:
    """vmap over seeds: both the ECMP path draw and the DCQCN coin flips vary."""
    cfg, mode = _resolve_routing(cfg, routing)
    statics = [build_static(topo, wl, mode, s, dt=cfg.dt, deploy=cfg.deploy,
                            **bg) for s in seeds]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *statics)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    wla = wl_arrays(wl, cfg.dt)
    fn = jax.vmap(lambda st, k: simulate_core(st, wla, cfg, k))
    return fn(stacked, keys)
