"""Ring-collective workloads for the Symphony simulator.

Model (paper §2.1-2.2):

* A *job* runs a sequence of ring collectives ("passes"); between passes there
  is an optional compute gap (Table 2's end-to-end model) and a job-wide
  barrier (gradient sync semantics).
* Each job owns one or more parallel 1-D rings over its hosts. Ring r of
  size N performs ``steps_per_pass = 2*(N-1)`` pipelined steps per pass; in
  each step every member sends one chunk to its successor.
* A *flow slot* f is one (ring, member): the persistent sender node->successor
  relationship. Its 5-tuple/path is fixed (per-flow ECMP) or re-hashed per
  step (per-step ECMP).
* Crucially, steps pipeline: node i may start sending step s as soon as it has
  *received* step s-1 (its predecessor finished sending s-1) — it does NOT
  wait for its own send of s-1 to drain.  Under congestion this produces
  multiple concurrent step-sends of one flow slot on the same path, which
  split bandwidth and cascade (Fig. 1e).  The simulator therefore tracks a
  window of concurrent *flow instances* per slot.
* Steps are numbered globally-monotonically across passes (the wire `step`
  field of §3.2; resets would be handled by Alg. 1's lazy correction anyway).

2-D ring collectives (§4.6) are expressed with two *phases* per pass: each
node has a dim-0 flow slot (phase 0) and a dim-1 slot (phase 1); a job-wide
barrier separates the phases.

The phase machinery generalizes beyond rings: recursive halving-doubling
allreduce is 2*log2(N) single-step phases with geometrically shrinking
chunks (:meth:`WorkloadBuilder.add_halving_doubling_job`), and hierarchical
allreduce is 3 phases — intra-group ring reduce-scatter, inter-group leader
ring, intra-group ring allgather (:meth:`WorkloadBuilder.add_hierarchical_job`).

Arrivals: fixed vs dependency-triggered
---------------------------------------
Jobs arrive either at a fixed ``start_time`` or via a **trigger rule**
(:meth:`WorkloadBuilder.set_trigger`): job j starts when job i completes
its c-th collective (plus an optional delay) — the CCL-Simulator-style
dependency-triggered injection.  Triggers are lowered to three traced
``[J]`` arrays (``trig_job`` / ``trig_seg`` / ``trig_delay``) that the
engine evaluates inside the tick (`stages.stage_segments`), so triggered
multi-tenant workloads run unchanged under the one-compile grid/shard
executors and the windowed checkpoint/resume core.
:meth:`WorkloadBuilder.add_poisson_churn` layers continuous tenant churn
on top: Poisson job arrivals over a host pool, each tenant departing when
its finite pass budget completes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .topology import Topology


@dataclass(frozen=True)
class Workload:
    """Flow-slot arrays (length F) + per-job arrays (length J)."""

    # --- flow slots ---
    src: np.ndarray          # [F] host id
    dst: np.ndarray          # [F] successor host id
    pred: np.ndarray         # [F] flow-slot index of ring predecessor
    job: np.ndarray          # [F] job id
    phase: np.ndarray        # [F] phase within a pass (0 for plain 1-D rings)
    steps_per_seg: np.ndarray  # [F] steps this slot runs per segment
    pass_steps: np.ndarray   # [F] steps per collective = 2(N-1) (boundaries)
    step_offset: np.ndarray  # [F] added to the wire step index (Fig. 9 style
                             #     scenarios where flows start mid-collective)
    flow_start: np.ndarray   # [F] per-flow start time (s), on top of job start
    # --- jobs ---
    n_phases: np.ndarray     # [J] phases per pass (1 or 2)
    n_passes: np.ndarray     # [J]
    chunk_sched: np.ndarray  # [J, max_segments] bytes per chunk in that segment
    compute_gap: np.ndarray  # [J] seconds inserted before each pass
    start_time: np.ndarray   # [J] job arrival time (s)
    # --- dependency-triggered arrivals (set_trigger; -1 = fixed start) ---
    trig_job: np.ndarray = None    # [J] job whose progress releases this one
    trig_seg: np.ndarray = None    # [J] segment count of trig_job to wait for
    trig_delay: np.ndarray = None  # [J] seconds between trigger and release

    def __post_init__(self):
        # Workloads built before the trigger fields existed (or constructed
        # directly in tests) default to all-fixed starts.
        J = int(self.n_phases.shape[0])
        if self.trig_job is None:
            object.__setattr__(self, "trig_job", np.full(J, -1, np.int32))
        if self.trig_seg is None:
            object.__setattr__(self, "trig_seg", np.zeros(J, np.int32))
        if self.trig_delay is None:
            object.__setattr__(self, "trig_delay", np.zeros(J, np.float64))

    @property
    def n_flows(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_jobs(self) -> int:
        return int(self.n_phases.shape[0])

    @property
    def max_segments(self) -> int:
        return int(self.chunk_sched.shape[1])

    def total_steps(self) -> np.ndarray:
        """[F] total steps each slot executes over the whole job."""
        return self.steps_per_seg * self.n_passes[self.job]


def _ring_slots(hosts: np.ndarray, ring_size: int, job_id: int, phase: int,
                flow_base: int):
    """Split `hosts` into interleaved rings of `ring_size` (stride layout:
    ring g = hosts[g::n_groups], matching Fig. 1a's 0-4-8-12 example)."""
    n = len(hosts)
    assert n % ring_size == 0, (n, ring_size)
    n_groups = n // ring_size
    src, dst, pred, phs = [], [], [], []
    idx = {}
    for g in range(n_groups):
        members = hosts[g::n_groups]
        for j in range(ring_size):
            idx[(g, j)] = flow_base + len(src)
            src.append(members[j])
            dst.append(members[(j + 1) % ring_size])
            phs.append(phase)
    for g in range(n_groups):
        for j in range(ring_size):
            pred.append(idx[(g, (j - 1) % ring_size)])
    return src, dst, pred, phs


class WorkloadBuilder:
    def __init__(self, max_segments: int | None = None):
        """``max_segments`` fixes the width of the chunk schedule: jobs with
        fewer segments are padded (repeating the last chunk); jobs with more
        raise at :meth:`build`.  Useful to keep array shapes — and therefore
        jit caches — stable across workloads."""
        self.max_segments = max_segments
        self._flows: dict[str, list] = {
            k: [] for k in ("src", "dst", "pred", "job", "phase", "sps", "ps",
                            "off", "fstart")}
        self._jobs: dict[str, list] = {k: [] for k in
                                       ("n_phases", "n_passes", "gap", "start", "chunks")}
        # job_id -> (after_job, collectives | None, delay_s)
        self._trigs: dict[int, tuple[int, int | None, float]] = {}

    def _pad_flow_defaults(self):
        n = len(self._flows["src"])
        for k in ("off", "fstart"):
            self._flows[k] += [0.0 if k == "fstart" else 0] * \
                (n - len(self._flows[k]))

    def add_ring_job(
        self,
        hosts: np.ndarray | list[int],
        ring_size: int,
        chunk_bytes: float | list[float] = 8e6,
        passes: int = 1,
        compute_gap: float = 0.0,
        start_time: float = 0.0,
        dims: tuple[int, ...] | None = None,
        barrier: bool = True,
    ) -> int:
        """Add one job. `dims=(d0, d1)` builds a 2-D ring collective instead of
        interleaved 1-D rings of `ring_size`.  `chunk_bytes` may be a list of
        per-pass chunk sizes (Table 2 layer-bucket schedules).

        ``barrier=False`` chains the passes back-to-back with only the ring
        data dependency between them (a pure communication benchmark, the
        paper's §2.2/§4.2 motivating workload): step misalignment can then
        accumulate *across* collective boundaries, which is how overlap
        degrees beyond 2(N-1) arise.  Requires scalar chunk_bytes and 1-D
        rings; compute_gap must be 0.
        """
        hosts = np.asarray(hosts, np.int32)
        job_id = len(self._jobs["n_passes"])
        base = len(self._flows["src"])
        if not barrier:
            assert dims is None and np.isscalar(chunk_bytes) and compute_gap == 0.0
            s, d, p, ph = _ring_slots(hosts, ring_size, job_id, 0, base)
            sps = [passes * 2 * (ring_size - 1)] * len(s)
            self._flows["src"] += list(s)
            self._flows["dst"] += list(d)
            self._flows["pred"] += list(p)
            self._flows["job"] += [job_id] * len(s)
            self._flows["phase"] += list(ph)
            self._flows["sps"] += sps
            self._flows["ps"] += [2 * (ring_size - 1)] * len(s)
            self._jobs["n_phases"].append(1)
            self._jobs["n_passes"].append(1)
            self._jobs["gap"].append(0.0)
            self._jobs["start"].append(float(start_time))
            self._jobs["chunks"].append([float(chunk_bytes)])
            return job_id
        if dims is None:
            s, d, p, ph = _ring_slots(hosts, ring_size, job_id, 0, base)
            sps = [2 * (ring_size - 1)] * len(s)
            n_phases = 1
        else:
            d0, d1 = dims
            assert d0 * d1 == len(hosts)
            grid = hosts.reshape(d0, d1)
            s, d, p, ph, sps = [], [], [], [], []
            # phase 0: rings along dim0 (columns), phase 1: rings along dim1 (rows)
            for c in range(d1):
                col = grid[:, c]
                s0, d0_, p0, _ = _ring_slots(col, d0, job_id, 0, base + len(s))
                s += s0; d += d0_; p += p0; ph += [0] * len(s0)
                sps += [2 * (d0 - 1)] * len(s0)
            for r in range(d0):
                row = grid[r, :]
                s1, d1_, p1, _ = _ring_slots(row, d1, job_id, 1, base + len(s))
                s += s1; d += d1_; p += p1; ph += [1] * len(s1)
                sps += [2 * (d1 - 1)] * len(s1)
            n_phases = 2
        self._flows["src"] += list(s)
        self._flows["dst"] += list(d)
        self._flows["pred"] += list(p)
        self._flows["job"] += [job_id] * len(s)
        self._flows["phase"] += list(ph)
        self._flows["sps"] += list(sps)
        self._flows["ps"] += list(sps)   # one collective per segment
        chunks = ([float(chunk_bytes)] * passes if np.isscalar(chunk_bytes)
                  else [float(c) for c in chunk_bytes])
        assert len(chunks) == passes, "per-pass chunk schedule must match passes"
        # segment k belongs to pass k // n_phases
        seg_chunks = [chunks[k // n_phases] for k in range(passes * n_phases)]
        self._jobs["n_phases"].append(n_phases)
        self._jobs["n_passes"].append(passes)
        self._jobs["gap"].append(float(compute_gap))
        self._jobs["start"].append(float(start_time))
        self._jobs["chunks"].append(seg_chunks)
        return job_id

    def add_chain_job(self, pairs, steps: int, chunk_bytes: float,
                      step_offsets=None, flow_starts=None,
                      start_time: float = 0.0) -> int:
        """Independent sender chains within ONE job (the Fig. 9 hardware
        scenario): each (src, dst) pair sends `steps` sequential chunks with
        no cross-flow gating; per-flow step_offsets place flows at different
        collective steps so Symphony sees outpacing vs lagging flows."""
        self._pad_flow_defaults()
        job_id = len(self._jobs["n_passes"])
        base = len(self._flows["src"])
        n = len(pairs)
        step_offsets = step_offsets or [0] * n
        flow_starts = flow_starts or [0.0] * n
        for i, (s, d) in enumerate(pairs):
            self._flows["src"].append(int(s))
            self._flows["dst"].append(int(d))
            self._flows["pred"].append(base + i)   # self-gated chain
            self._flows["job"].append(job_id)
            self._flows["phase"].append(0)
            self._flows["sps"].append(steps)
            self._flows["ps"].append(steps)
            self._flows["off"].append(int(step_offsets[i]))
            self._flows["fstart"].append(float(flow_starts[i]))
        self._jobs["n_phases"].append(1)
        self._jobs["n_passes"].append(1)
        self._jobs["gap"].append(0.0)
        self._jobs["start"].append(float(start_time))
        self._jobs["chunks"].append([float(chunk_bytes)])
        return job_id

    def _add_phase_slots(self, s, d, p, ph, sps, job_id):
        self._flows["src"] += list(s)
        self._flows["dst"] += list(d)
        self._flows["pred"] += list(p)
        self._flows["job"] += [job_id] * len(s)
        self._flows["phase"] += list(ph)
        self._flows["sps"] += list(sps)
        self._flows["ps"] += list(sps)   # one collective per segment

    def add_halving_doubling_job(
        self,
        hosts: np.ndarray | list[int],
        chunk_bytes: float = 8e6,
        passes: int = 1,
        compute_gap: float = 0.0,
        start_time: float = 0.0,
    ) -> int:
        """Recursive halving-doubling allreduce (Swing/Rabenseifner style).

        ``chunk_bytes`` is the *total* reduced volume V.  The collective runs
        2*log2(N) barrier-separated phases: reduce-scatter exchanges of
        V/2, V/4, .., V/N with partners at distance 1, 2, .., N/2, then the
        mirrored allgather doubling back up.  Each phase is one step per
        node, so each (node, phase) is its own self-gated flow slot.
        """
        hosts = np.asarray(hosts, np.int32)
        n = len(hosts)
        m = int(np.log2(n))
        assert 2 ** m == n, f"halving-doubling needs power-of-2 hosts, got {n}"
        self._pad_flow_defaults()
        job_id = len(self._jobs["n_passes"])
        n_phases = 2 * m
        for q in range(n_phases):
            dist = 1 << (q if q < m else 2 * m - 1 - q)
            base = len(self._flows["src"])
            s = list(hosts)
            d = [int(hosts[i ^ dist]) for i in range(n)]
            p = [base + i for i in range(n)]       # self-gated, 1 step
            self._add_phase_slots(s, d, p, [q] * n, [1] * n, job_id)
        seg_chunks = [float(chunk_bytes) / 2 ** (min(q, n_phases - 1 - q) + 1)
                      for _ in range(passes) for q in range(n_phases)]
        self._jobs["n_phases"].append(n_phases)
        self._jobs["n_passes"].append(passes)
        self._jobs["gap"].append(float(compute_gap))
        self._jobs["start"].append(float(start_time))
        self._jobs["chunks"].append(seg_chunks)
        return job_id

    def add_hierarchical_job(
        self,
        hosts: np.ndarray | list[int],
        group_size: int,
        chunk_bytes: float = 8e6,
        passes: int = 1,
        compute_gap: float = 0.0,
        start_time: float = 0.0,
    ) -> int:
        """Hierarchical allreduce: intra-group ring reduce-scatter (phase 0),
        inter-group ring allreduce over group leaders (phase 1), intra-group
        ring allgather (phase 2).  Groups are contiguous runs of
        ``group_size`` hosts, which maps onto ToR locality when hosts are
        numbered contiguously per ToR (topology convention)."""
        hosts = np.asarray(hosts, np.int32)
        n, g = len(hosts), int(group_size)
        assert n % g == 0 and n // g >= 2, (n, g)
        n_groups = n // g
        self._pad_flow_defaults()
        job_id = len(self._jobs["n_passes"])
        groups = [hosts[i * g:(i + 1) * g] for i in range(n_groups)]
        for phase, sps in ((0, g - 1), (2, g - 1)):
            if g == 1:
                continue
            for mem in groups:
                base = len(self._flows["src"])
                s, d, p, _ = _ring_slots(mem, g, job_id, phase, base)
                self._add_phase_slots(s, d, p, [phase] * len(s),
                                      [sps] * len(s), job_id)
        base = len(self._flows["src"])
        leader_phase = 1 if g > 1 else 0
        leaders = np.asarray([mem[0] for mem in groups], np.int32)
        s, d, p, _ = _ring_slots(leaders, n_groups, job_id, leader_phase, base)
        self._add_phase_slots(s, d, p, [leader_phase] * len(s),
                              [2 * (n_groups - 1)] * len(s), job_id)
        n_phases = 3 if g > 1 else 1
        # per-phase exchanged volume: ring RS/AG move V/g per step inside a
        # group; the leader ring reduces each group's shard of V.
        per_phase = ([float(chunk_bytes) / g,
                      float(chunk_bytes) / (g * n_groups),
                      float(chunk_bytes) / g] if g > 1
                     else [float(chunk_bytes) / n_groups])
        seg_chunks = [c for _ in range(passes) for c in per_phase]
        self._jobs["n_phases"].append(n_phases)
        self._jobs["n_passes"].append(passes)
        self._jobs["gap"].append(float(compute_gap))
        self._jobs["start"].append(float(start_time))
        self._jobs["chunks"].append(seg_chunks)
        return job_id

    def set_trigger(self, job: int, after_job: int, collectives: int | None = None,
                    delay: float = 0.0) -> None:
        """Make ``job`` a dependency-triggered arrival: it is released when
        ``after_job`` completes its ``collectives``-th collective (pass),
        plus ``delay`` seconds.  ``collectives=None`` waits for the whole
        job (every pass) — chained tenant hand-off.

        The trigger replaces the fixed ``start_time``: the engine holds the
        job's segment barrier closed (``seg_ready = INT32_MAX``) until the
        dependency fires *inside the simulation*, so trigger evaluation is
        traced and works unchanged under vmap/grids and windowed resume.
        """
        J = len(self._jobs["n_passes"])
        if not 0 <= job < J or not 0 <= after_job < J:
            raise ValueError(f"trigger references unknown job ({job}, "
                             f"{after_job}); have {J} jobs")
        if job == after_job:
            raise ValueError(f"job {job} cannot trigger on itself")
        if collectives is not None and collectives < 1:
            raise ValueError(f"collectives must be >= 1, got {collectives}")
        if delay < 0:
            raise ValueError(f"trigger delay must be >= 0, got {delay}")
        self._trigs[job] = (after_job, collectives, float(delay))

    def add_poisson_churn(self, host_groups, rate_hz: float, horizon_s: float,
                          ring_size: int | None = None,
                          chunk_bytes: float = 4e6, passes: int = 1,
                          seed: int = 0, max_jobs: int | None = None
                          ) -> list[int]:
        """Continuous tenant churn: Poisson job *arrivals* over a pool of
        host groups, each tenant *departing* when its finite ``passes``
        budget completes.  Arrival k lands on ``host_groups[k % G]`` (a
        tenant's host allocation) at the k-th Poisson event time; times are
        sampled host-side from ``seed`` so the workload is reproducible and
        lowers to plain traced start-tick arrays — the whole churn replay
        runs under one compile of the engine.

        Returns the job ids in arrival order.
        """
        if rate_hz <= 0 or horizon_s <= 0:
            raise ValueError(f"need rate_hz > 0 and horizon_s > 0, got "
                             f"({rate_hz}, {horizon_s})")
        groups = [np.asarray(g, np.int32) for g in host_groups]
        if not groups:
            raise ValueError("empty host_groups")
        rng = np.random.default_rng(seed)
        jobs, t, k = [], 0.0, 0
        while True:
            t += float(rng.exponential(1.0 / rate_hz))
            if t >= horizon_s or (max_jobs is not None and k >= max_jobs):
                break
            g = groups[k % len(groups)]
            rs = len(g) if ring_size is None else min(ring_size, len(g))
            jobs.append(self.add_ring_job(
                hosts=g, ring_size=rs, chunk_bytes=chunk_bytes,
                passes=passes, barrier=False, start_time=t))
            k += 1
        return jobs

    def build(self) -> Workload:
        self._pad_flow_defaults()
        max_seg = max(len(c) for c in self._jobs["chunks"])
        if self.max_segments is not None:
            if max_seg > self.max_segments:
                raise ValueError(
                    f"job needs {max_seg} segments > max_segments="
                    f"{self.max_segments}")
            max_seg = self.max_segments
        J = len(self._jobs["n_passes"])
        sched = np.zeros((J, max_seg), np.float64)
        for j, c in enumerate(self._jobs["chunks"]):
            sched[j, :len(c)] = c
            if len(c) < max_seg:           # pad with last value (unused segs)
                sched[j, len(c):] = c[-1]
        trig_job = np.full(J, -1, np.int32)
        trig_seg = np.zeros(J, np.int32)
        trig_delay = np.zeros(J, np.float64)
        for j, (after, colls, delay) in self._trigs.items():
            n_segs = len(self._jobs["chunks"][after])
            nph = self._jobs["n_phases"][after]
            want = n_segs if colls is None else colls * nph
            if want > n_segs:
                raise ValueError(
                    f"job {j} triggers on collective {colls} of job {after}, "
                    f"which only runs {n_segs // nph} collectives")
            trig_job[j], trig_seg[j], trig_delay[j] = after, want, delay
        return Workload(
            src=np.asarray(self._flows["src"], np.int32),
            dst=np.asarray(self._flows["dst"], np.int32),
            pred=np.asarray(self._flows["pred"], np.int32),
            job=np.asarray(self._flows["job"], np.int32),
            phase=np.asarray(self._flows["phase"], np.int32),
            steps_per_seg=np.asarray(self._flows["sps"], np.int32),
            pass_steps=np.asarray(self._flows["ps"], np.int32),
            step_offset=np.asarray(self._flows["off"], np.int32),
            flow_start=np.asarray(self._flows["fstart"], np.float64),
            n_phases=np.asarray(self._jobs["n_phases"], np.int32),
            n_passes=np.asarray(self._jobs["n_passes"], np.int32),
            chunk_sched=sched,
            compute_gap=np.asarray(self._jobs["gap"], np.float64),
            start_time=np.asarray(self._jobs["start"], np.float64),
            trig_job=trig_job, trig_seg=trig_seg, trig_delay=trig_delay,
        )


def path_table_for(topo: Topology, wl: Workload
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Per-flow ECMP candidate paths: ``(paths [F, P, H], n_paths [F])``."""
    return topo.candidate_paths(wl.src, wl.dst)


def routes_for(topo: Topology, wl: Workload, choice: np.ndarray) -> np.ndarray:
    """[F, H] link ids (null-link = topo.n_links for unused hops) given a
    per-flow candidate-path choice (applied modulo each flow's fan-out)."""
    paths, n_paths = path_table_for(topo, wl)
    return paths[np.arange(wl.n_flows), np.asarray(choice) % n_paths]


def ecmp_choice(topo: Topology, wl: Workload, seed: int) -> np.ndarray:
    """Per-flow 5-tuple-hash path selection (persistent across steps)."""
    paths, _ = path_table_for(topo, wl)
    rng = np.random.default_rng(seed)
    return rng.integers(0, paths.shape[1], wl.n_flows).astype(np.int64)


def balanced_choice(topo: Topology, wl: Workload) -> np.ndarray:
    """Static balanced routing: round-robin over each source edge switch's
    candidate paths (the paper's controlled 'static balanced' scenarios,
    Fig. 2).  Flows with a single path (intra-ToR) are skipped."""
    _, n_paths = path_table_for(topo, wl)
    st = topo.tor_of(wl.src)
    choice = np.zeros(wl.n_flows, np.int64)
    counters: dict[int, int] = {}
    for f in range(wl.n_flows):
        if n_paths[f] <= 1:
            continue  # single-path flows never touch the fabric
        t = int(st[f])
        c = counters.get(t, 0)
        choice[f] = c % n_paths[f]
        counters[t] = c + 1
    return choice

