"""Post-processing of SimResult into the paper's metrics (numpy, host-side)."""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .simulator import I32MAX, WIRE_SEG, SimParams, SimResult
from .workload import Workload


def _np(x):
    return np.asarray(x)


def overlap_series(res: SimResult, cfg: SimParams, job: int = 0):
    """Degree of step overlap over time (Fig. 2a / 4a): number of distinct
    steps concurrently in flight. Returns (t_seconds, overlap)."""
    mn = _np(res.ts_min_wire)[..., job].astype(np.int64)
    mx = _np(res.ts_max_wire)[..., job].astype(np.int64)
    has = mx >= 0
    # Within a segment, wire differences equal step differences; the job-wide
    # barrier guarantees no cross-segment concurrency, so this is exact.
    ov = np.where(has, mx - mn + 1, 0)
    t = (np.arange(mn.shape[-1]) + 1.0) * cfg.record_every * cfg.dt
    return t, ov


def step_completion_times(res: SimResult, cfg: SimParams, job: int = 0):
    """Times (s) at which the job-wide min completed-step counter advanced."""
    dm = _np(res.ts_done_min)[..., job]
    t = (np.arange(dm.shape[-1]) + 1.0) * cfg.record_every * cfg.dt
    times = []
    last = 0
    for i, v in enumerate(dm):
        v = int(v)
        while last < v:
            last += 1
            times.append(t[i])
    return np.asarray(times)


def step_completion_rate(res: SimResult, cfg: SimParams, job: int = 0,
                         smooth: int = 4):
    """Normalized step completion rate (Fig. 2b): inverse inter-step interval,
    normalized by the ideal per-step time."""
    times = step_completion_times(res, cfg, job)
    if len(times) < 2 + smooth:
        return np.asarray([]), np.asarray([])
    iv = np.diff(times)
    iv = np.convolve(iv, np.ones(smooth) / smooth, mode="valid")
    rate = 1.0 / np.maximum(iv, 1e-9)
    return times[1 + smooth - 1:], rate


def cct_seconds(res: SimResult, wl: Workload, cfg: SimParams) -> np.ndarray:
    """Per-job collective/job completion time (finish - start), seconds.
    Works on batched results (leading seed axes)."""
    jf = _np(res.job_finish_ticks).astype(np.float64)
    start = np.asarray(wl.start_time) / cfg.dt
    out = (jf - start) * cfg.dt
    return np.where(jf >= I32MAX, np.nan, out)


def flow_span_seconds(res: SimResult, wl: Workload, cfg: SimParams,
                      job: int = 0) -> np.ndarray:
    """Span of the final collective step: completion-time spread between the
    fastest and slowest flow of a job (Fig. 7b)."""
    ft = _np(res.finish_ticks).astype(np.float64)
    mask = np.asarray(wl.job) == job
    sel = ft[..., mask]
    return (sel.max(axis=-1) - sel.min(axis=-1)) * cfg.dt


def ideal_cct(wl: Workload, job: int, link_bps: float) -> float:
    """Theoretical lockstep lower bound: every step takes chunk/bandwidth,
    steps are serial, plus compute gaps.  Handles multi-phase collectives
    (2-D rings, halving-doubling, hierarchical) whose phases run different
    step counts: segment k serializes the max step count among the flows
    participating in phase k % n_phases."""
    jmask = np.asarray(wl.job) == job
    sps_f = np.asarray(wl.steps_per_seg)[jmask]
    phase_f = np.asarray(wl.phase)[jmask]
    passes = int(np.asarray(wl.n_passes)[job])
    nph = int(np.asarray(wl.n_phases)[job])
    phase_sps = np.asarray([sps_f[phase_f == q].max() for q in range(nph)])
    per_seg = np.asarray(wl.chunk_sched)[job, :passes * nph]
    seg_sps = phase_sps[np.arange(passes * nph) % nph]
    comm = float(np.sum(per_seg * seg_sps / link_bps))
    return comm + passes * float(np.asarray(wl.compute_gap)[job])


def max_overlap(res: SimResult, cfg: SimParams, job: int = 0):
    """Maximum step-overlap over the run (supports batched results)."""
    _, ov = overlap_series(res, cfg, job)
    return ov.max(axis=-1)


# --------------------------------------------- online control-plane summaries
class WindowStats(NamedTuple):
    """Host-side summary of one control window's sampled series — the
    observation an online tuner reacts to (``control.SimController``)."""
    alpha_max: float         # max Symphony alpha over the window
    alpha_last: float        # alpha at the window's final sample
    qmax: float              # max queue depth (bytes) over the window
    q_last: float            # queue depth at the final sample
    tput: np.ndarray         # [J] window-mean delivered bytes/s per job
    tput_last: np.ndarray    # [J] delivered bytes/s at the final sample
    done_min: np.ndarray     # [J] min completed local steps (final sample)
    overlap: np.ndarray      # [J] in-flight wire-step span (final sample)


def window_summary(samples) -> WindowStats:
    """Reduce a :class:`~repro.core.netsim.simulator.WindowSamples` (or any
    SimResult-shaped series bundle) to one :class:`WindowStats`."""
    mn = _np(samples.ts_min_wire)[-1].astype(np.int64)
    mx = _np(samples.ts_max_wire)[-1].astype(np.int64)
    tput = _np(samples.ts_throughput)
    q = _np(samples.ts_qmax)
    al = _np(samples.ts_alpha_max)
    return WindowStats(
        alpha_max=float(al.max()),
        alpha_last=float(al[-1]),
        qmax=float(q.max()),
        q_last=float(q[-1]),
        tput=tput.mean(axis=0),
        tput_last=tput[-1],
        done_min=_np(samples.ts_done_min)[-1],
        overlap=np.where(mx >= 0, mx - mn + 1, 0),
    )
