"""Symphony's per-job switch state machine (paper Alg. 1, Eq. 1-5).

This module is the *exact*, packet-granular reproduction of the paper's
contribution: the Per-Job State Block kept by a switch egress port, the
selective-throttling marking decision, and the windowed adaptive
aggressiveness update.  Everything is pure JAX (jit/vmap/scan-able) so the
same code drives

  * unit / property tests (tests/test_symphony.py),
  * the Pallas "switch pipeline" kernel oracle (kernels/switch_pipeline/ref.py),
  * the fluid network simulator (core/netsim/simulator.py), which reuses the
    marking math through :func:`marking_probability`.

Terminology follows the paper:
  step      logical ring-collective stage s_0 .. s_n of a job
  psn       packet sequence number within the flow (fluid model: bytes/MTU)
  LAST bit  RDMA WRITE "LAST" flag == step-completion signal
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "SymphonyParams",
    "SymphonyState",
    "init_state",
    "process_packet",
    "window_update",
    "marking_probability",
    "process_packet_batch",
]


class SymphonyParams(NamedTuple):
    """Static control parameters (paper Table 1 + §3.3/§3.4 defaults)."""

    k: jax.Array | float = 0.01          # throttling gain (Eq. 4)
    tau: jax.Array | float = 0.25        # outpacing tolerance threshold (Eq. 3)
    n_warmup: jax.Array | int = 16       # psn_rec warm-up guard (Alg. 1 l.11)
    n_sample: jax.Array | int = 32       # Sample Guard for the window update
    alpha_max: jax.Array | float = 64.0  # numerical cap on alpha(t)


class SymphonyState(NamedTuple):
    """Per-(egress port, job) state block.

    All fields are scalars; vmap over leading axes for multi-port/multi-job.
    """

    step_min: jax.Array   # i32 — global synchronization anchor
    psn_rec: jax.Array    # f32 — time-windowed max PSN within step_min
    alpha: jax.Array      # f32 — adaptive aggressiveness factor, >= 1
    cnt_total: jax.Array  # f32 — packets seen in current window
    cnt_op: jax.Array     # f32 — outpacing packets in current window


def init_state(dtype=jnp.float32) -> SymphonyState:
    return SymphonyState(
        step_min=jnp.zeros((), jnp.int32),
        psn_rec=jnp.zeros((), dtype),
        alpha=jnp.ones((), dtype),
        cnt_total=jnp.zeros((), dtype),
        cnt_op=jnp.zeros((), dtype),
    )


def marking_probability(
    step: jax.Array,
    psn: jax.Array,
    step_min: jax.Array,
    psn_rec: jax.Array,
    alpha: jax.Array,
    params: SymphonyParams,
) -> jax.Array:
    """Eq. 1 + Eq. 4 with the Alg. 1 guards; returns P(mark) in [0, 1].

    Lagging/aligned packets (step <= step_min) and warm-up windows
    (psn_rec <= N_warmup) are never marked by Symphony.
    """
    outpacing = step > step_min
    warm = psn_rec > jnp.asarray(params.n_warmup, psn_rec.dtype)
    delta = alpha * (psn.astype(psn_rec.dtype) / jnp.maximum(psn_rec, 1.0))
    p = jnp.minimum(1.0, jnp.asarray(params.k, psn_rec.dtype) * delta)
    return jnp.where(outpacing & warm, p, 0.0)


class Packet(NamedTuple):
    step: jax.Array      # i32
    psn: jax.Array       # i32/f32
    is_last: jax.Array   # bool — RDMA WRITE LAST bit


def process_packet(
    state: SymphonyState,
    pkt: Packet,
    params: SymphonyParams,
    uniform: jax.Array,
) -> tuple[SymphonyState, jax.Array]:
    """One dequeued packet through Alg. 1. Returns (state', to_mark_ecn).

    `uniform` is a U[0,1) sample implementing TossCoin; pass 1.0 to obtain the
    deterministic no-mark decision or compare against the probability
    directly via :func:`marking_probability`.
    """
    step = jnp.asarray(pkt.step, jnp.int32)
    psn = jnp.asarray(pkt.psn, state.psn_rec.dtype)

    # l.2 UpdateTrafficStats — uses the state *before* this packet's update.
    is_op = step > state.step_min
    cnt_total = state.cnt_total + 1.0
    cnt_op = state.cnt_op + is_op.astype(state.cnt_op.dtype)

    # l.3-10 progress tracking: optimistic advancement + lazy correction.
    is_last = jnp.asarray(pkt.is_last, bool)
    lt = step < state.step_min
    eq = step == state.step_min
    step_min = jnp.where(is_last, step + 1, jnp.where(lt, step, state.step_min))
    psn_rec = jnp.where(
        is_last,
        0.0,
        jnp.where(lt, psn, jnp.where(eq, jnp.maximum(state.psn_rec, psn), state.psn_rec)),
    )

    # l.11-17 marking decision — evaluated against the *pre-update* anchors,
    # matching the sequential switch pipeline (the packet that advances the
    # state is itself judged by the state it found on arrival).
    p = marking_probability(step, psn, state.step_min, state.psn_rec, state.alpha, params)
    to_mark = uniform < p

    new = SymphonyState(step_min=step_min, psn_rec=psn_rec, alpha=state.alpha,
                        cnt_total=cnt_total, cnt_op=cnt_op)
    return new, to_mark


def window_update(state: SymphonyState, params: SymphonyParams) -> SymphonyState:
    """End-of-T_win update: Eq. 2/3 via the integer test of Eq. 5.

    * Sample Guard: skipped entirely when cnt_total <= N_sample.
    * alpha moves by +-1, clamped to [1, alpha_max].
    * Window counters reset; psn_rec resets (time-windowed max, §3.4.2).
    """
    have_samples = state.cnt_total > jnp.asarray(params.n_sample, state.cnt_total.dtype)
    exceed = state.cnt_op >= jnp.asarray(params.tau, state.cnt_op.dtype) * state.cnt_total
    delta = jnp.where(exceed, 1.0, -1.0)
    alpha = jnp.where(have_samples, state.alpha + delta, state.alpha)
    alpha = jnp.clip(alpha, 1.0, jnp.asarray(params.alpha_max, alpha.dtype))
    zero = jnp.zeros_like(state.cnt_total)
    return SymphonyState(step_min=state.step_min, psn_rec=zero, alpha=alpha,
                         cnt_total=zero, cnt_op=zero)


def process_packet_batch(
    state: SymphonyState,
    steps: jax.Array,
    psns: jax.Array,
    is_lasts: jax.Array,
    uniforms: jax.Array,
    params: SymphonyParams,
) -> tuple[SymphonyState, jax.Array]:
    """Sequentially process a batch of packets (lax.scan over Alg. 1).

    This is the oracle for the Pallas switch-pipeline kernel: the ASIC
    processes packets one-by-one through the stateful ALUs; marks[i] is the
    decision for packet i given all packets < i.
    """

    def body(st, x):
        step, psn, last, u = x
        st, mark = process_packet(st, Packet(step, psn, last), params, u)
        return st, mark

    state, marks = jax.lax.scan(body, state, (steps, psns, is_lasts, uniforms))
    return state, marks
