"""Explicit ring collectives built from `lax.ppermute` (inside shard_map).

These make the 2(N-1)-step structure that Symphony aligns *visible in the
HLO* as chains of collective-permute ops — unlike XLA's fused all-reduce.
The trainer exposes `--grad-sync ring` to synchronize gradients with these
(paper-faithful path); `xla` uses psum (the beyond-paper baseline for the
roofline comparison).

All functions run under shard_map manual axes and operate on the *local
shard* of each device.  Conventions:

  ring_reduce_scatter(x, axis) : x local [n*k, ...] -> [k, ...] reduced shard
  ring_all_gather(x, axis)     : x local [k, ...]   -> [n*k, ...]
  ring_all_reduce(x, axis)     : x local [...]      -> [...] sum over axis

Multi-channel: `channels=c` splits the tensor into c interleaved chunks and
runs c rings concurrently (NCCL channel semantics — exactly the "multiple
parallel 1-D rings" of paper Fig. 1a).  Bidirectional rings split each chunk
in half and pipeline the two directions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _axis_size(axis: str) -> int:
    from ..compat import axis_size
    return axis_size(axis)


def _perm(n: int, shift: int = 1):
    return [(i, (i + shift) % n) for i in range(n)]


def ring_reduce_scatter(x: jax.Array, axis: str, reverse: bool = False
                        ) -> jax.Array:
    """x: [n*k, ...] local -> [k, ...]: this device's shard of the sum.

    Step s: each device sends its running partial to the successor and adds
    the local chunk for the shard now being accumulated.  n-1 steps, each
    moving k elements — bandwidth-optimal.  The unrolled permutes appear as
    an explicit collective-permute chain in HLO (the "steps" Symphony
    aligns).
    """
    n = _axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    k = x.shape[0] // n
    chunks = x.reshape((n, k) + x.shape[1:])
    sgn = -1 if reverse else 1
    perm = _perm(n, sgn)
    acc = jnp.take(chunks, (idx - sgn) % n, axis=0)
    for s in range(1, n):
        acc = jnp.take(chunks, (idx - sgn * (s + 1)) % n, axis=0) + \
            jax.lax.ppermute(acc, axis, perm)
    return acc


def ring_all_gather(x: jax.Array, axis: str, reverse: bool = False
                    ) -> jax.Array:
    """x: [k, ...] local shard -> [n*k, ...] full, ring-pipelined."""
    n = _axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    sgn = -1 if reverse else 1
    perm = _perm(n, sgn)
    pieces = [x]
    cur = x
    for _ in range(n - 1):
        cur = jax.lax.ppermute(cur, axis, perm)
        pieces.append(cur)
    # device idx holds shards [idx, idx-sgn, idx-2sgn, ...]; scatter them into
    # position with a single static concat + roll.
    stack = jnp.stack(pieces)                       # [n, k, ...]
    offs = (idx - sgn * jnp.arange(n)) % n          # source shard ids
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = out.at[offs].set(stack)
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def ring_all_reduce(x: jax.Array, axis: str, channels: int = 1,
                    bidirectional: bool = False) -> jax.Array:
    """Flat ring all-reduce = reduce-scatter + all-gather, 2(N-1) steps.

    channels > 1 splits into parallel rings (NCCL channels); bidirectional
    runs half the data around each ring direction.
    """
    n = _axis_size(axis)
    if n == 1:
        return x
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % (n * channels * (2 if bidirectional else 1))
    if pad:
        flat = jnp.pad(flat, (0, pad))

    def one_ring(v, reverse):
        rs = ring_reduce_scatter(v, axis, reverse)
        return ring_all_gather(rs, axis, reverse)

    parts = flat.reshape(channels * (2 if bidirectional else 1), -1)
    outs = []
    for c in range(parts.shape[0]):
        rev = bidirectional and (c % 2 == 1)
        outs.append(one_ring(parts[c], rev))
    out = jnp.stack(outs).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(shape)


def ring_all_reduce_nd(x: jax.Array, axis: str) -> jax.Array:
    """Ring all-reduce chunking along dim 0 WITHOUT flattening: trailing dims
    keep their (auto/TP) sharding, so the permute payload stays the local
    shard.  (Flattening a TP-sharded gradient first forces a 16x all-gather —
    measured in EXPERIMENTS.md §Perf iteration 3.)"""
    n = _axis_size(axis)
    if n == 1:
        return x
    orig = x.shape
    if x.ndim == 0:
        x = x.reshape(1)
    pad = (-x.shape[0]) % n
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    out = ring_all_gather(ring_reduce_scatter(x, axis), axis)
    if pad:
        out = out[:-pad]
    return out.reshape(orig)


def hierarchical_all_reduce(x: jax.Array, inner_axis: str, outer_axis: str,
                            channels: int = 1, compress=None) -> jax.Array:
    """Multi-pod gradient sync: ring reduce-scatter intra-pod, ring
    all-reduce of the shard across pods (DCN hop — the tier the paper's
    fabric represents), then ring all-gather intra-pod.

    Wire cost per chip: 2S(n-1)/n intra + 2S'(p-1)/p inter with S' = S/n —
    the inter-pod traffic is 1/n of a naive flat all-reduce across all chips.
    `compress` = (encode, decode) pair applied around the inter-pod hop
    (e.g. int8 error-feedback, optim/compress.py).
    """
    n = _axis_size(inner_axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % (n * channels)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = ring_reduce_scatter(flat, inner_axis)
    if compress is not None:
        encode, decode = compress
        shard_q, meta = encode(shard)
        shard_q = ring_all_reduce(shard_q, outer_axis, channels=channels)
        shard = decode(shard_q, meta)
    else:
        shard = ring_all_reduce(shard, outer_axis, channels=channels)
    out = ring_all_gather(shard, inner_axis)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)
