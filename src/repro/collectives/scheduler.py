"""Step-aligned gradient-bucket scheduler (host-side Symphony counterpart).

The in-network mechanism (core/symphony.py) aligns ring steps *inside the
fabric*; the framework keeps the sender side aligned by

  1. bucketizing gradients into fixed-size buckets (NCCL-style), so every
     ring step moves a uniform volume (the paper's uniformity assumption,
     §3.2 "Traffic granularity"),
  2. issuing buckets in reverse layer order (sync overlaps backward compute),
  3. shrinking the bucket size when the straggler monitor reports high
     step-time jitter — smaller steps bound the damage a single slow step
     can do (the chunk-size effect of paper Fig. 8c).

`sync_grads_local` must be called INSIDE a shard_map region that is manual
over the data axes (see runtime/train.py `make_train_step(grad_sync="ring")`)
— partial per-device gradients are only representable there.  The 'model'
axis stays auto (GSPMD), so TP collectives coexist with the explicit rings.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .ring import (hierarchical_all_reduce, ring_all_reduce,
                   ring_all_reduce_nd)


@dataclass(frozen=True)
class BucketPlan:
    bucket_of: tuple[tuple[int, ...], ...]   # leaf indices per bucket
    bucket_bytes: int


def plan_buckets(sizes: list[int], bucket_bytes: int = 32 << 20,
                 dtype_bytes: int = 4) -> BucketPlan:
    """Greedy reverse-order bucketing (grads become ready last-layer-first)."""
    buckets: list[list[int]] = [[]]
    acc = 0
    for i in reversed(range(len(sizes))):
        buckets[-1].append(i)
        acc += sizes[i] * dtype_bytes
        if acc >= bucket_bytes:
            buckets.append([])
            acc = 0
    if not buckets[-1]:
        buckets.pop()
    return BucketPlan(bucket_of=tuple(tuple(b) for b in buckets),
                      bucket_bytes=bucket_bytes)


def sync_grads_local(grads, axes: tuple[str, ...], *, mode: str = "ring",
                     channels: int = 4, bidirectional: bool = False,
                     bucket_bytes: int = 32 << 20, compress=None,
                     mean: bool = True):
    """All-reduce a gradient pytree over manual mesh `axes`.

    mode: 'ring' (flat rings over each axis), 'hierarchical' (intra-pod ring
    reduce-scatter + inter-pod ring on the shard + intra-pod all-gather), or
    'psum' (XLA collective — the comparison baseline).

    compress: optional (encode, decode) from optim/compress.py applied around
    the inter-pod hop of hierarchical sync (error-feedback int8).
    """
    leaves, treedef = jax.tree.flatten(grads)
    if not axes:
        return grads
    from ..compat import axis_size
    n_total = 1
    for ax in axes:
        n_total *= axis_size(ax)

    if mode == "psum":
        out = [jax.lax.psum(l, axes) for l in leaves]
        if mean:
            out = [o / n_total for o in out]
        return jax.tree.unflatten(treedef, out)

    from .. import flags
    wire_dtype = jnp.dtype(flags.RING_SYNC_DTYPE)
    # Leaf-wise rings chunked along dim 0: flattening TP-sharded gradients
    # into one buffer would force an all-gather over the model axis first
    # (16x the wire — §Perf iteration 3).  Buckets still gate issue order.
    sizes = [int(np.prod(l.shape)) for l in leaves]
    plan = plan_buckets(sizes, bucket_bytes)
    out_leaves: list = [None] * len(leaves)
    for bucket in plan.bucket_of:
        for i in bucket:
            g = leaves[i].astype(wire_dtype)
            if mode == "hierarchical" and "pod" in axes and len(axes) == 2:
                inner = axes[1] if axes[0] == "pod" else axes[0]
                red = hierarchical_all_reduce(
                    g.reshape(-1), inner_axis=inner, outer_axis="pod",
                    channels=channels, compress=compress).reshape(g.shape)
            else:
                red = g
                for ax in axes:
                    red = ring_all_reduce_nd(red, ax)
            if mean:
                red = red / n_total
            out_leaves[i] = red.astype(leaves[i].dtype)
    return jax.tree.unflatten(treedef, out_leaves)
