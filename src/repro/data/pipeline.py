"""Deterministic, sharded, resumable synthetic LM data pipeline.

Tokens are drawn from a Zipf-like distribution with a deterministic
per-(step, host_shard) PRNG, so any host can reproduce any step's batch
without coordination — checkpoint/restart and *elastic* restarts (different
data-parallel world size) resume exactly: the iterator state is just the
step counter.

A file-backed mode memory-maps a pre-generated token binary and serves
strided windows (exercises the real I/O path in examples/tests).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    path: str | None = None        # file-backed mode


class SyntheticLM:
    """next-token-prediction batches with a learnable structure: token t+1
    depends on t via a fixed random permutation + noise, so a real model can
    drive the loss well below the unigram entropy (used to validate
    end-to-end training)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._perm = rng.permutation(cfg.vocab_size)
        # zipf-ish unigram distribution over a capped support
        ranks = np.arange(1, cfg.vocab_size + 1)
        p = 1.0 / ranks ** cfg.zipf_a
        self._p = p / p.sum()
        self._mmap = None
        if cfg.path:
            self._mmap = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch(self, step: int, shard: int = 0, n_shards: int = 1):
        """Returns (tokens, labels) [B/n_shards, S] for this host shard."""
        cfg = self.cfg
        bsz = cfg.global_batch // n_shards
        if self._mmap is not None:
            S = cfg.seq_len
            n_tok = self._mmap.shape[0] - S - 1
            starts = (np.arange(bsz) * 9973 + step * 31337 +
                      shard * 7919) % n_tok
            tokens = np.stack([self._mmap[s: s + S] for s in starts])
            labels = np.stack([self._mmap[s + 1: s + S + 1] for s in starts])
            return tokens.astype(np.int32), labels.astype(np.int32)
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard]))
        first = rng.choice(cfg.vocab_size, size=(bsz, 1), p=self._p)
        noise = rng.random((bsz, cfg.seq_len)) < 0.15
        rnd = rng.choice(cfg.vocab_size, size=(bsz, cfg.seq_len), p=self._p)
        seq = np.empty((bsz, cfg.seq_len + 1), np.int32)
        seq[:, :1] = first
        for t in range(cfg.seq_len):
            det = self._perm[seq[:, t]]
            seq[:, t + 1] = np.where(noise[:, t], rnd[:, t], det)
        return seq[:, :-1].copy(), seq[:, 1:].copy()

    @staticmethod
    def write_corpus(path: str | Path, n_tokens: int, vocab: int,
                     seed: int = 0):
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1)
        p = (1.0 / ranks ** 1.2)
        p /= p.sum()
        toks = rng.choice(vocab, size=n_tokens, p=p).astype(np.int32)
        toks.tofile(str(path))
        return path


class Prefetcher:
    """Background-thread prefetch of the next batch (overlaps host data
    generation with the device step)."""

    def __init__(self, source: SyntheticLM, start_step: int, shard: int = 0,
                 n_shards: int = 1, depth: int = 2):
        import queue
        import threading
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = False
        self.step = start_step

        def worker():
            s = start_step
            while not self._stop:
                self._q.put((s, source.batch(s, shard, n_shards)))
                s += 1

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self):
        step, batch = self._q.get()
        self.step = step
        return step, batch

    def close(self):
        self._stop = True
        try:
            while True:
                self._q.get_nowait()
        except Exception:
            pass
