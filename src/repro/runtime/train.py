"""Training runtime: jitted step (GSPMD or explicit-ring grad sync),
checkpoint/restart fault tolerance, straggler monitor, elastic restarts.

Fault model (matching what a 1000-node deployment needs):
  * node failure -> the job restarts from the latest checkpoint; since data
    order is a pure function of (seed, step), training is bit-reproducible
    across restarts.
  * elastic restart -> the restore mesh may have a different data-parallel
    width; checkpoints store global arrays, so restore just re-shards.
  * stragglers -> per-step wall-time EMA + z-score detector; persistent
    stragglers shrink the gradient-sync bucket size (smaller ring steps =
    less damage per slow step — paper Fig. 8c), and the event log feeds the
    cluster scheduler.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..collectives.scheduler import sync_grads_local
from ..config import ModelConfig, ParallelConfig, TrainConfig
from ..checkpoint.manager import CheckpointManager
from ..data.pipeline import DataConfig, SyntheticLM
from ..optim.adamw import OptState, adamw_update, init_opt_state
from ..launch.steps import cross_entropy


def make_loss_fn(model, cfg: ModelConfig):
    def loss_fn(params, batch):
        logits, aux = model.apply(params, batch["tokens"])
        return cross_entropy(logits[..., :cfg.vocab_size],
                             batch["labels"]) + aux
    return loss_fn


def make_train_step(model, cfg: ModelConfig, tcfg: TrainConfig,
                    par: ParallelConfig, mesh):
    """Returns a jitted (params, opt, batch) -> (params, opt, metrics).

    grad_sync='xla'  : GSPMD inserts the gradient all-reduce (baseline).
    grad_sync='ring' / 'hierarchical': the whole step runs under a shard_map
    that is MANUAL over the data axes, and gradients are synchronized by the
    explicit ppermute ring collectives (collectives/ring.py) with NCCL-style
    bucketing — the paper-faithful pipeline whose steps Symphony aligns.
    """
    loss_fn = make_loss_fn(model, cfg)

    def base_step(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, metrics = adamw_update(params, grads, opt, tcfg)
        metrics["loss"] = loss
        return params, opt, metrics

    if par.grad_sync == "xla" or mesh is None:
        return jax.jit(base_step, donate_argnums=(0, 1))

    data_axes = tuple(a for a in ("pod", "data") if mesh.shape.get(a, 1) > 1)

    def manual_step(params, opt, batch):
        def local_loss(p, b):
            loss, grads = jax.value_and_grad(loss_fn)(p, b)
            grads = sync_grads_local(
                grads, data_axes,
                mode="hierarchical" if par.grad_sync == "hierarchical"
                else "ring",
                channels=par.ring_buckets,
                bidirectional=par.ring_bidirectional)
            loss = jax.lax.pmean(loss, data_axes)
            return loss, grads
        loss, grads = local_loss(params, batch)
        params, opt, metrics = adamw_update(params, grads, opt, tcfg)
        metrics["loss"] = loss
        return params, opt, metrics

    # manual over data axes; 'model' stays auto (GSPMD handles TP inside)
    batch_spec = {"tokens": P(data_axes, None), "labels": P(data_axes, None)}

    def wrapped(params, opt, batch):
        from ..compat import shard_map
        fn = shard_map(
            manual_step, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), params),
                      jax.tree.map(lambda _: P(), opt),
                      batch_spec),
            out_specs=(jax.tree.map(lambda _: P(), params),
                       jax.tree.map(lambda _: P(), opt),
                       {"loss": P(), "lr": P(), "grad_norm": P()}),
            axis_names=set(data_axes))
        return fn(params, opt, batch)

    return jax.jit(wrapped, donate_argnums=(0, 1))


@dataclass
class StragglerMonitor:
    """EMA + z-score step-time anomaly detector (host side)."""
    alpha: float = 0.1
    z_thresh: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.n > 5:
            sd = max(np.sqrt(self.var), 1e-6)
            if (dt - self.mean) / sd > self.z_thresh:
                self.events.append((step, dt, self.mean))
                self._update(dt)
                return True
        self._update(dt)
        return False

    def _update(self, dt: float):
        if self.n == 0:
            self.mean = dt
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.n += 1


@dataclass
class TrainerReport:
    steps_run: int
    final_loss: float
    losses: list
    restarts: int
    straggler_events: int


class Trainer:
    """End-to-end training driver with checkpoint/restart resilience."""

    def __init__(self, model, cfg: ModelConfig, tcfg: TrainConfig,
                 par: ParallelConfig, mesh=None,
                 failure_injector=None):
        self.model = model
        self.cfg = cfg
        self.tcfg = tcfg
        self.par = par
        self.mesh = mesh
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep,
                                      async_write=tcfg.ckpt_async)
        self.monitor = StragglerMonitor()
        self.failure_injector = failure_injector
        self.step_fn = make_train_step(model, cfg, tcfg, par, mesh)
        self.data = SyntheticLM(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed))

    def _init_state(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = self.model.init(key)
        opt = init_opt_state(params, self.tcfg)
        return params, opt

    def run(self, steps: int | None = None) -> TrainerReport:
        steps = steps or self.tcfg.total_steps
        params, opt = self._init_state()
        start = 0
        latest = self.ckpt.latest_step()
        restarts = 0
        if latest is not None:
            (params, opt), extra = self.ckpt.restore(
                latest, (params, opt))
            params = jax.tree.map(jnp.asarray, params)
            opt = jax.tree.map(jnp.asarray, opt)
            start = extra["step"] + 1
        losses = []
        s = start
        while s < steps:
            try:
                if self.failure_injector is not None:
                    self.failure_injector(s)
                toks, labs = self.data.batch(s)
                t0 = time.time()
                params, opt, metrics = self.step_fn(
                    params, opt, {"tokens": jnp.asarray(toks),
                                  "labels": jnp.asarray(labs)})
                loss = float(metrics["loss"])
                self.monitor.observe(s, time.time() - t0)
                losses.append(loss)
                if (s + 1) % self.tcfg.ckpt_every == 0 or s == steps - 1:
                    self.ckpt.save(s, (params, opt), {"step": s})
                s += 1
            except SimulatedFailure:
                restarts += 1
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                params, opt = self._init_state()
                if latest is not None:
                    (params, opt), extra = self.ckpt.restore(
                        latest, (params, opt))
                    params = jax.tree.map(jnp.asarray, params)
                    opt = jax.tree.map(jnp.asarray, opt)
                    s = extra["step"] + 1
                else:
                    s = 0
        self.ckpt.wait()
        return TrainerReport(steps_run=steps - start,
                             final_loss=losses[-1] if losses else float("nan"),
                             losses=losses, restarts=restarts,
                             straggler_events=len(self.monitor.events))


class SimulatedFailure(Exception):
    """Raised by failure injectors to emulate a node crash."""
