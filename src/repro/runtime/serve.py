"""Batched serving engine: continuous batching over a request queue.

prefill is chunked (prefill_chunk tokens per pass over the cached decode
path is wasteful, so prefill uses the full forward and writes the cache via
one batched pass per request group); decode steps run the whole active batch
through `model.decode_step`.  Slots free as requests hit max_tokens/EOS and
are refilled from the queue — the standard continuous-batching loop.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig, ServeConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, cfg: ModelConfig, scfg: ServeConfig,
                 params):
        self.model = model
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        B, S = scfg.batch, scfg.max_seq
        self.cache = model.init_cache(B, S)
        self.pos = np.zeros(B, np.int32)
        self.active: list[Request | None] = [None] * B
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.scfg.batch):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                self.active[slot] = req
                # prefill token-by-token through the decode path (correct if
                # slow on CPU; TPU deployments use the chunked prefill step)
                self.pos[slot] = 0
                self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request):
        for t in req.prompt:
            tokens = np.zeros((self.scfg.batch, 1), np.int32)
            tokens[slot, 0] = t
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(self.pos))
            self.pos[slot] += 1
        req._next = int(jnp.argmax(logits[slot, -1]))

    def step(self) -> int:
        """One decode step for the whole active batch. Returns #finished."""
        self._admit()
        tokens = np.zeros((self.scfg.batch, 1), np.int32)
        for slot, req in enumerate(self.active):
            if req is not None:
                tokens[slot, 0] = getattr(req, "_next", 0)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        finished = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(tokens[slot, 0]))
            req._next = int(nxt[slot])
            self.pos[slot] += 1
            if len(req.out) >= req.max_new_tokens or \
                    self.pos[slot] >= self.scfg.max_seq - 1:
                req.done = True
                self.active[slot] = None
                finished += 1
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        done: list[Request] = []
        all_reqs = list(self.queue)
        for _ in range(max_steps):
            self.step()
            if not self.queue and all(a is None for a in self.active):
                break
        return [r for r in all_reqs if r.done]
