"""Attention: GQA (+ sliding window), full train/prefill path and cached
decode path.  The train/prefill softmax attention dispatches to the Pallas
flash kernel when enabled, else to the jnp reference.

Shapes: activations are [batch, seq, d_model]; q/k/v are
[batch, seq, heads, head_dim].  Decode KV caches are
[batch, kv_heads, max_seq, head_dim] and may be sequence-sharded across mesh
axes — the decode path computes partial softmax statistics per shard and
combines with log-sum-exp (distributed flash-decode).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from ..parallel.sharding import padded
from .layers import apply_mrope, apply_rope
from .params import ParamSpec

NEG_INF = -1e30


def attn_spec(cfg: ModelConfig, tp: int, layers: int | None = None) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh = padded(cfg.num_heads, tp)
    # MHA: pad kv heads with q heads; GQA: kv heads stay (replicated under TP)
    nkv = nh if cfg.num_kv_heads == cfg.num_heads else cfg.num_kv_heads
    lead = (layers,) if layers is not None else ()
    la = ("layers",) if layers is not None else ()
    return {
        "wq": ParamSpec(lead + (d, nh, hd), la + ("embed", "heads", "head_dim")),
        "wk": ParamSpec(lead + (d, nkv, hd), la + ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec(lead + (d, nkv, hd), la + ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec(lead + (nh, hd, d), la + ("heads", "head_dim", "embed")),
    }


def effective_kv_heads(cfg: ModelConfig, tp: int) -> int:
    """KV head count after TP padding (matches attn_spec)."""
    nh = padded(cfg.num_heads, tp)
    return nh if cfg.num_kv_heads == cfg.num_heads else cfg.num_kv_heads


def _mask_bias(q_pos, k_pos, window: int) -> jax.Array:
    """[.. , Sq, Sk] additive mask: causal (+ sliding window if window>0)."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        ok &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def ref_attention(q, k, v, q_pos, k_pos, window: int = 0,
                  cross: bool = False) -> jax.Array:
    """Reference softmax attention with GQA head-group mapping.

    q: [B, Sq, Hq, D]; k/v: [B, Sk, Hkv, D].  fp32 softmax.
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(D)
    if not cross:
        logits = logits + _mask_bias(q_pos, k_pos, window)[:, None, None]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def ref_attention_chunked(q, k, v, q_pos, k_pos, window: int = 0,
                          cross: bool = False, chunk: int = 512) -> jax.Array:
    """Streaming (flash-style) reference: scan over q blocks so the logits
    transient is [B, Hq, chunk, Sk] instead of [B, Hq, Sq, Sk].  Same FLOPs,
    bounded memory — this is what the dry-run HLO lowers for long sequences
    (the Pallas kernel is the TPU-native equivalent)."""
    B, Sq, Hq, D = q.shape
    assert Sq % chunk == 0, (Sq, chunk)

    def blk(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * chunk, chunk, axis=-1) \
            if q_pos is not None else None
        return ref_attention(qs, k, v, qp, k_pos, window=window, cross=cross)

    out = jax.lax.map(blk, jnp.arange(Sq // chunk))     # [nc, B, chunk, H, D]
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hq, D)


def flash_or_ref(q, k, v, q_pos, k_pos, window: int = 0, cross: bool = False,
                 use_flash: bool = False) -> jax.Array:
    from .. import flags
    if use_flash and not cross:
        from ..kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, q_pos, k_pos, window=window)
    if q.shape[1] > 2048 and not flags.ROOFLINE_MODE:
        return ref_attention_chunked(q, k, v, q_pos, k_pos, window=window,
                                     cross=cross)
    return ref_attention(q, k, v, q_pos, k_pos, window=window, cross=cross)


class KVCache(NamedTuple):
    k: jax.Array        # [B, Hkv, S, D]
    v: jax.Array        # [B, Hkv, S, D]


def project_qkv(p: dict, x: jax.Array, cfg: ModelConfig, positions,
                rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if rope and cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif rope and cfg.pos_emb == "mrope":
        if positions.shape[-1] != 3:       # text-only: t = h = w
            positions = jnp.broadcast_to(positions[..., None],
                                         positions.shape + (3,))
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def attention_block(p: dict, x: jax.Array, cfg: ModelConfig,
                    positions: jax.Array, use_flash: bool = False) -> jax.Array:
    """Full (train / prefill) self-attention."""
    q, k, v = project_qkv(p, x, cfg, positions)
    pos1d = positions[..., 0] if cfg.pos_emb == "mrope" else positions
    o = flash_or_ref(q, k, v, pos1d, pos1d, window=cfg.sliding_window,
                     use_flash=use_flash)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def decode_attention(p: dict, x: jax.Array, cfg: ModelConfig,
                     cache: KVCache, pos: jax.Array) -> tuple[jax.Array, KVCache]:
    """One-token decode against a KV cache.

    x: [B, 1, d]; pos: [B] current position.  The cache may be sharded along
    its sequence axis; the partial-softmax combine below is shard-local math
    followed by lane-invariant reductions, so GSPMD lowers it to an
    all-reduce of (num, den) pairs instead of gathering the cache.
    """
    B = x.shape[0]
    q, k_new, v_new = project_qkv(p, x, cfg, pos[:, None])
    # ring-buffer write for sliding windows; plain write otherwise
    wpos = (pos % cfg.sliding_window) if cfg.sliding_window else pos
    bidx = jnp.arange(B)
    # cache layout [B, Hkv, S, D]; k_new[:, 0] is [B, Hkv, D]
    k_cache = cache.k.at[bidx, :, wpos].set(k_new[:, 0])
    v_cache = cache.v.at[bidx, :, wpos].set(v_new[:, 0])
    o = cached_attention(q, KVCache(k_cache, v_cache), pos,
                         window=cfg.sliding_window)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, KVCache(k_cache, v_cache)


def cached_attention(q: jax.Array, cache: KVCache, pos: jax.Array,
                     window: int = 0) -> jax.Array:
    """q: [B, 1, Hq, D]; cache k/v: [B, Hkv, S, D]; pos: [B].

    Computes softmax(q k^T) v with masking of unwritten / out-of-window slots,
    in the numerically safe two-pass (max, exp-sum) form.
    """
    B, _, Hq, D = q.shape
    Hkv, S = cache.k.shape[1], cache.k.shape[2]
    g = Hq // Hkv
    qg = q[:, 0].reshape(B, Hkv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg,
                        cache.k.astype(jnp.float32)) / np.sqrt(D)
    slot = jnp.arange(S)
    if window:
        # ring buffer of length `window`: once pos >= window every slot holds
        # an in-window position; before that only slots <= pos are written.
        valid = (slot[None] <= pos[:, None]) | (pos[:, None] >= window)
    else:
        valid = slot[None] <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    e = jnp.exp(logits - m)
    num = jnp.einsum("bhgs,bhsd->bhgd", e, cache.v.astype(jnp.float32))
    den = e.sum(-1, keepdims=True)
    out = num / jnp.maximum(den, 1e-30)
    return out.reshape(B, 1, Hq, D).astype(cache.v.dtype)
