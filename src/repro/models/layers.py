"""Shared building blocks: norms, MLPs, embeddings, RoPE / M-RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from .params import ParamSpec

# ---------------------------------------------------------------- norms


def norm_spec(cfg: ModelConfig, layers: int | None = None) -> dict:
    shape = (cfg.d_model,)
    axes: tuple = ("norm",)
    if layers is not None:
        shape = (layers,) + shape
        axes = ("layers",) + axes
    d = {"scale": ParamSpec(shape, axes, init="ones", dtype=jnp.float32)}
    if cfg.norm == "layernorm":
        d["bias"] = ParamSpec(shape, axes, init="zeros", dtype=jnp.float32)
    return d


def apply_norm(p: dict, x: jax.Array, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------- MLP


def mlp_spec(cfg: ModelConfig, d_ff: int, layers: int | None = None) -> dict:
    d = cfg.d_model
    lead = (layers,) if layers is not None else ()
    la = ("layers",) if layers is not None else ()
    if cfg.activation == "swiglu":
        return {
            "wi": ParamSpec(lead + (d, 2, d_ff), la + ("embed", None, "mlp")),
            "wo": ParamSpec(lead + (d_ff, d), la + ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec(lead + (d, d_ff), la + ("embed", "mlp")),
        "wo": ParamSpec(lead + (d_ff, d), la + ("mlp", "embed")),
    }


def apply_mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.activation == "swiglu":
        gu = jnp.einsum("...d,dtf->...tf", x, p["wi"])
        g, u = gu[..., 0, :], gu[..., 1, :]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"])
        if cfg.activation == "relu2":        # squared ReLU (nemotron-4)
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------- embeddings


def embed_spec(cfg: ModelConfig, padded_vocab: int) -> dict:
    d = {"embedding": ParamSpec((padded_vocab, cfg.d_model),
                                ("vocab", "embed"), init="normal", scale=1.0)}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamSpec((cfg.d_model, padded_vocab),
                                 ("embed", "vocab"), init="fan_in")
    return d


def apply_embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def apply_unembed(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["embedding"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["unembed"])
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


# ---------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs        # [..., S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]   # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, ...]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): positions [..., seq, 3] = (t, h, w) ids;
    frequency bands are partitioned across the three position streams."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)       # [half]
    sec_id = jnp.asarray(
        np.repeat(np.arange(len(sections)), sections), jnp.int32)  # [half]
    # gather per-band positions: band_pos[..., s, i] = positions[..., s, sec_id[i]]
    p = positions.astype(jnp.float32)                              # [..., S, 3]
    band_pos = jnp.take(p, sec_id, axis=-1)                       # [..., S, half]
    ang = band_pos * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def learned_pos_spec(cfg: ModelConfig, max_pos: int) -> dict:
    return {"pos_embedding": ParamSpec((max_pos, cfg.d_model),
                                       (None, "embed"), init="normal",
                                       scale=0.02)}
