"""Mamba-2 SSD (state-space duality) blocks.

Train/prefill uses the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk state recurrence); this pure-jnp implementation is also the
oracle for the Pallas `ssd` kernel.  Decode is the O(1)-per-token recurrence
with a conv ring state.

Shapes: x_in [B, S, d_model]; internal heads H = d_inner / head_dim (padded
for TP); state N = cfg.ssm.d_state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from ..parallel.sharding import padded
from .params import ParamSpec


def ssm_dims(cfg: ModelConfig, tp: int) -> tuple[int, int]:
    """(padded heads, d_inner_padded)."""
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    h = padded(d_inner // s.head_dim, tp)
    return h, h * s.head_dim


def ssm_spec(cfg: ModelConfig, tp: int, layers: int | None = None) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    H, d_in = ssm_dims(cfg, tp)
    hd = s.head_dim
    N = s.d_state
    lead = (layers,) if layers is not None else ()
    la = ("layers",) if layers is not None else ()
    return {
        "wz": ParamSpec(lead + (d, H, hd), la + ("embed", "ssm_heads", "head_dim")),
        "wx": ParamSpec(lead + (d, H, hd), la + ("embed", "ssm_heads", "head_dim")),
        "wB": ParamSpec(lead + (d, N), la + ("embed", "state")),
        "wC": ParamSpec(lead + (d, N), la + ("embed", "state")),
        "wdt": ParamSpec(lead + (d, H), la + ("embed", "ssm_heads")),
        "dt_bias": ParamSpec(lead + (H,), la + ("ssm_heads",), init="zeros",
                             dtype=jnp.float32),
        "A_log": ParamSpec(lead + (H,), la + ("ssm_heads",), init="constant",
                           scale=0.5, dtype=jnp.float32),
        "D": ParamSpec(lead + (H,), la + ("ssm_heads",), init="ones",
                       dtype=jnp.float32),
        "conv_x": ParamSpec(lead + (s.d_conv, H, hd),
                            la + ("conv", "ssm_heads", "head_dim"),
                            init="normal", scale=0.3),
        "conv_BC": ParamSpec(lead + (s.d_conv, 2 * N), la + ("conv", "state"),
                             init="normal", scale=0.3),
        "norm": ParamSpec(lead + (H, hd), la + ("ssm_heads", "head_dim"),
                          init="ones", dtype=jnp.float32),
        "wo": ParamSpec(lead + (H, hd, d), la + ("ssm_heads", "head_dim", "embed")),
    }


def _causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. u: [B, S, C]; w: [K, C]."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + u.shape[1]] * w[i] for i in range(K))
    return out


def segsum_exp(a: jax.Array) -> jax.Array:
    """L[i, j] = exp(sum_{j<k<=i} a_k) for i>=j else 0.  a: [..., Q]."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]       # [..., i, j] = sum(j..i]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_reference(x: jax.Array, a: jax.Array, Bm: jax.Array, Cm: jax.Array,
                  chunk: int, init_state: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (sequential over chunks, like the Pallas kernel).

    x: [B, S, H, P] inputs (already dt-scaled)
    a: [B, S, H]    log-decay per step (dt * A, negative)
    Bm, Cm: [B, S, N] input/output projections (single group, shared by heads)
    Returns (y [B,S,H,P], final_state [B,H,P,N]).

    The per-chunk body is rematerialized, so the [Q, Q] decay/score matrices
    never exist for more than one chunk at a time — the memory profile the
    Pallas kernel has natively.
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q
    xc = x.reshape(B, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    ac = a.reshape(B, nc, Q, H).astype(jnp.float32).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32).transpose(1, 0, 2, 3)

    s0 = jnp.zeros((B, H, P, N), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    @jax.checkpoint
    def body(state, inp):
        xk, ak, Bk, Ck = inp                           # [B,Q,H,P] etc.
        cum = jnp.cumsum(ak, axis=1)                   # [B,Q,H]
        L = segsum_exp(ak.transpose(0, 2, 1))          # [B,H,Q,Q]
        G = jnp.einsum("bqn,bkn->bqk", Ck, Bk)         # [B,Q,Q]
        y = jnp.einsum("bhqk,bkhp->bqhp", G[:, None] * L,
                       xk.astype(jnp.float32))
        y += jnp.einsum("bqn,bhpn,bqh->bqhp", Ck, state, jnp.exp(cum))
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)   # [B,Q,H]
        new_state = state * jnp.exp(cum[:, -1, :])[..., None, None] + \
            jnp.einsum("bqn,bqh,bqhp->bhpn", Bk, decay_to_end,
                       xk.astype(jnp.float32))
        return new_state, y

    final, ys = jax.lax.scan(body, s0, (xc, ac, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, final


def ssd_reference_vec(x: jax.Array, a: jax.Array, Bm: jax.Array,
                      Cm: jax.Array, chunk: int) -> tuple[jax.Array, jax.Array]:
    """Loop-free (fully vectorized over chunks) SSD — used by the roofline
    lowering where lax.scan bodies would be cost-counted once.  Memory-heavy;
    the production path is the scanned `ssd_reference`.

    flags.SSD_BF16 keeps the O(Q^2) decay/score tensors in bf16 (the §Perf
    lever for the memory-bound mamba2 cells); the cumulative-sum / exp math
    and the inter-chunk state stay fp32.
    """
    from .. import flags
    wdt = jnp.bfloat16 if flags.SSD_BF16 else jnp.float32
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q
    xc = x.reshape(B, nc, Q, H, P)
    ac = a.reshape(B, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N).astype(wdt)
    Cc = Cm.reshape(B, nc, Q, N).astype(wdt)

    L = segsum_exp(ac.transpose(0, 1, 3, 2)).astype(wdt)  # [B,nc,H,Q,Q]
    G = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", (G[:, :, None] * L).astype(wdt),
                        xc.astype(wdt),
                        preferred_element_type=jnp.float32)
    cum = jnp.cumsum(ac, axis=2)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum).astype(wdt)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_to_end,
                        xc.astype(wdt),
                        preferred_element_type=jnp.float32)
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # [B,nc,H]
    # inter-chunk recurrence, unrolled (nc is small)
    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    entering = []
    cur = s0
    for c in range(nc):
        entering.append(cur)
        cur = cur * chunk_decay[:, c][..., None, None] + states[:, c]
    entering = jnp.stack(entering, axis=1)             # [B,nc,H,P,N]
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, entering, jnp.exp(cum))
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, cur


class SSMCache(NamedTuple):
    conv: jax.Array     # [B, K-1, H*hd + 2N] last conv inputs
    state: jax.Array    # [B, H, hd, N]


def _proj_inputs(p: dict, x_in: jax.Array, cfg: ModelConfig):
    s = cfg.ssm
    z = jnp.einsum("bsd,dhp->bshp", x_in, p["wz"])
    xh = jnp.einsum("bsd,dhp->bshp", x_in, p["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x_in, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x_in, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x_in, p["wdt"])
    return z, xh, Bm, Cm, dt


def ssm_block(p: dict, x_in: jax.Array, cfg: ModelConfig,
              use_kernel: bool = False) -> jax.Array:
    """Train/prefill SSD mixer. x_in: [B, S, d_model]."""
    s = cfg.ssm
    B, S, _ = x_in.shape
    z, xh, Bm, Cm, dt = _proj_inputs(p, x_in, cfg)
    H, hd = xh.shape[2], xh.shape[3]
    N = Bm.shape[-1]
    # causal conv + silu on (x, B, C)
    u = jnp.concatenate([xh.reshape(B, S, H * hd), Bm, Cm], axis=-1)
    w = jnp.concatenate([p["conv_x"].reshape(s.d_conv, H * hd),
                         p["conv_BC"]], axis=-1)
    u = jax.nn.silu(_causal_conv(u, w).astype(jnp.float32)).astype(x_in.dtype)
    xh = u[..., : H * hd].reshape(B, S, H, hd)
    Bm, Cm = u[..., H * hd: H * hd + N], u[..., H * hd + N:]

    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = dtp * A                                        # [B,S,H] log decay
    xs = xh.astype(jnp.float32) * dtp[..., None]
    if use_kernel:
        from ..kernels.ssd.ops import ssd
        y, _ = ssd(xs, a, Bm, Cm, chunk=s.chunk_size)
    else:
        from .. import flags
        pad = (-S) % s.chunk_size
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        fn = ssd_reference_vec if flags.ROOFLINE_MODE else ssd_reference
        y, _ = fn(xs, a, Bm, Cm, chunk=s.chunk_size)
        y = y[:, :S]
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    # gated RMSNorm then output projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = (y ** 2).mean(-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6) * p["norm"]).astype(x_in.dtype)
    return jnp.einsum("bshp,hpd->bsd", y, p["wo"])


def ssm_decode(p: dict, x_in: jax.Array, cfg: ModelConfig, cache: SSMCache
               ) -> tuple[jax.Array, SSMCache]:
    """One-token recurrence. x_in: [B, 1, d_model]."""
    s = cfg.ssm
    B = x_in.shape[0]
    z, xh, Bm, Cm, dt = _proj_inputs(p, x_in, cfg)
    H, hd = xh.shape[2], xh.shape[3]
    N = Bm.shape[-1]
    u_new = jnp.concatenate([xh.reshape(B, 1, H * hd), Bm, Cm], axis=-1)
    # conv ring state: [B, K-1, C] of previous inputs
    window = jnp.concatenate([cache.conv, u_new], axis=1)   # [B, K, C]
    w = jnp.concatenate([p["conv_x"].reshape(s.d_conv, H * hd),
                         p["conv_BC"]], axis=-1)
    u = jax.nn.silu(jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w))
    xh1 = u[:, : H * hd].reshape(B, H, hd)
    Bm1, Cm1 = u[:, H * hd: H * hd + N], u[:, H * hd + N:]

    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtp * A)                                  # [B,H]
    xs = xh1.astype(jnp.float32) * dtp[..., None]
    new_state = cache.state * decay[..., None, None] + \
        jnp.einsum("bhp,bn->bhpn", xs, Bm1.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm1.astype(jnp.float32))
    y = y + xh1.astype(jnp.float32) * p["D"][:, None]
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    var = (y ** 2).mean(-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6) * p["norm"]).astype(x_in.dtype)
    out = jnp.einsum("bhp,hpd->bd", y, p["wo"])[:, None]
    return out, SSMCache(conv=window[:, 1:], state=new_state)


def init_ssm_cache(cfg: ModelConfig, batch: int, tp: int) -> SSMCache:
    s = cfg.ssm
    H, d_in = ssm_dims(cfg, tp)
    return SSMCache(
        conv=jnp.zeros((batch, s.d_conv - 1, d_in + 2 * s.d_state),
                       jnp.bfloat16),
        state=jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    )
