"""Declarative parameter trees.

Models declare parameters as `ParamSpec` descriptors (shape + logical axes +
initializer).  The same tree then serves three purposes:

* `init_tree(key, tree)`        — materialize real weights (training / tests)
* `abstract_tree(tree, ...)`    — ShapeDtypeStructs with NamedShardings for
                                  the multi-pod dry-run (no allocation)
* `shardings_tree(tree, ...)`   — in_shardings for jit
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import sharding_for


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "fan_in"          # fan_in | normal | zeros | ones | constant
    scale: float = 1.0
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = dict  # nested dict[str, ParamTree | ParamSpec]


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_leaves_with_path(tree: ParamTree, prefix=()):
    for k, v in tree.items():
        if _is_spec(v):
            yield prefix + (k,), v
        else:
            yield from tree_leaves_with_path(v, prefix + (k,))


def _init_leaf(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "constant":
        return jnp.full(spec.shape, spec.scale, spec.dtype)
    if spec.init == "fan_in":
        # fan-in = product of dims up to the last ("output") dim heuristic:
        # all but the trailing axis count as input dims for our conventions.
        fan = max(1, math.prod(spec.shape[:-1])) if len(spec.shape) > 1 \
            else spec.shape[0]
        std = spec.scale / math.sqrt(fan)
    else:  # normal
        std = spec.scale
    x = jax.random.truncated_normal(key, -3.0, 3.0, spec.shape, jnp.float32)
    return (x * std).astype(spec.dtype)


def init_tree(key: jax.Array, tree: ParamTree) -> dict:
    leaves = list(tree_leaves_with_path(tree))
    keys = jax.random.split(key, len(leaves))
    flat = {path: _init_leaf(k, spec) for (path, spec), k in zip(leaves, keys)}
    return _unflatten(flat)


def _unflatten(flat: Mapping[tuple, Any]) -> dict:
    out: dict = {}
    for path, v in flat.items():
        d = out
        for k in path[:-1]:
            d = d.setdefault(k, {})
        d[path[-1]] = v
    return out


def abstract_tree(tree: ParamTree, rules, mesh) -> dict:
    flat = {}
    for path, spec in tree_leaves_with_path(tree):
        sh = sharding_for(spec.axes, rules, mesh) if mesh is not None else None
        flat[path] = jax.ShapeDtypeStruct(spec.shape, spec.dtype, sharding=sh)
    return _unflatten(flat)


def shardings_tree(tree: ParamTree, rules, mesh) -> dict:
    flat = {path: sharding_for(spec.axes, rules, mesh)
            for path, spec in tree_leaves_with_path(tree)}
    return _unflatten(flat)


def count_params(tree: ParamTree) -> int:
    return sum(math.prod(s.shape) for _, s in tree_leaves_with_path(tree))


def param_bytes(tree: ParamTree) -> int:
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
               for _, s in tree_leaves_with_path(tree))


def cast_tree(params: dict, dtype) -> dict:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
