"""Model factory: ModelConfig -> LM or EncDec with mesh-aware sharding."""
from __future__ import annotations

from ..config import ModelConfig, ParallelConfig
from ..parallel.sharding import make_rules
from .encdec import EncDec
from .lm import LM


def build_model(cfg: ModelConfig, par: ParallelConfig | None = None,
                mesh=None, rules=None, use_flash: bool = False,
                use_ssd_kernel: bool = False):
    par = par or ParallelConfig()
    if rules is None and mesh is not None:
        rules = make_rules(fsdp=par.fsdp,
                           seq_shard_decode=par.seq_shard_decode)
    if cfg.family == "encdec":
        return EncDec(cfg, par, mesh=mesh, rules=rules, use_flash=use_flash)
    return LM(cfg, par, mesh=mesh, rules=rules, use_flash=use_flash,
              use_ssd_kernel=use_ssd_kernel)
