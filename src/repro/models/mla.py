"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Train/prefill materialize per-head K/V from the compressed latent; decode
keeps only the latent cache [B, S, kv_lora_rank] + shared rope key
[B, S, rope_dim] and uses the *absorbed* formulation:

    score(s) = (Wuk_h^T q_nope_h) . c_s + q_rope_h . k_rope_s
    out_h    = Wuv_h ( sum_s softmax(score)_s c_s )

so the per-token decode cost is O(S * (rank + rope_dim)) per head instead of
materializing O(S * head_dim) K/V — the reason MLA long-context serving is
cheap, and exactly the kind of compute/memory trade the roofline analysis
tracks.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from ..parallel.sharding import padded
from .attention import NEG_INF, flash_or_ref
from .layers import apply_rope
from .params import ParamSpec


def mla_spec(cfg: ModelConfig, tp: int, layers: int | None = None) -> dict:
    m = cfg.mla
    d = cfg.d_model
    nh = padded(cfg.num_heads, tp)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    lead = (layers,) if layers is not None else ()
    la = ("layers",) if layers is not None else ()
    return {
        "wq_a": ParamSpec(lead + (d, m.q_lora_rank), la + ("embed", "q_lora")),
        "q_norm": ParamSpec(lead + (m.q_lora_rank,), la + ("norm",),
                            init="ones", dtype=jnp.float32),
        "wq_b": ParamSpec(lead + (m.q_lora_rank, nh, qk),
                          la + ("q_lora", "heads", "head_dim")),
        "wkv_a": ParamSpec(lead + (d, m.kv_lora_rank + m.qk_rope_head_dim),
                           la + ("embed", "kv_lora")),
        "kv_norm": ParamSpec(lead + (m.kv_lora_rank,), la + ("norm",),
                             init="ones", dtype=jnp.float32),
        "wk_b": ParamSpec(lead + (m.kv_lora_rank, nh, m.qk_nope_head_dim),
                          la + ("kv_lora", "heads", "head_dim")),
        "wv_b": ParamSpec(lead + (m.kv_lora_rank, nh, m.v_head_dim),
                          la + ("kv_lora", "heads", "head_dim")),
        "wo": ParamSpec(lead + (nh, m.v_head_dim, d),
                        la + ("heads", "head_dim", "embed")),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps) * scale
    return y.astype(x.dtype)


def _project(p, x, cfg, positions):
    m = cfg.mla
    ql = _rms(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c = _rms(kv[..., : m.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)                     # [B,S,1,rope]
    return q_nope, q_rope, c, k_rope


class MLACache(NamedTuple):
    c: jax.Array        # [B, S, kv_lora_rank] latent
    k_rope: jax.Array   # [B, S, rope_dim]


def mla_block(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
              use_flash: bool = False) -> jax.Array:
    """Train/prefill: materialize per-head K/V from the latent."""
    m = cfg.mla
    q_nope, q_rope, c, k_rope = _project(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c, p["wv_b"])
    nh = q_nope.shape[2]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (nh,) +
                                  k_rope.shape[3:])], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad V up to the qk head dim so flash kernels see uniform shapes
    o = flash_or_ref(q, k,
                     jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                 (0, q.shape[-1] - v.shape[-1]))),
                     positions, positions, window=0, use_flash=use_flash)
    o = o[..., : m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def mla_decode(p: dict, x: jax.Array, cfg: ModelConfig, cache: MLACache,
               pos: jax.Array) -> tuple[jax.Array, MLACache]:
    """Absorbed decode with latent cache. x: [B, 1, d], pos: [B]."""
    m = cfg.mla
    q_nope, q_rope, c_new, k_rope_new = _project(p, x, cfg, pos[:, None])
    B = x.shape[0]
    bidx = jnp.arange(B)
    c_cache = cache.c.at[bidx, pos].set(c_new[:, 0])
    r_cache = cache.k_rope.at[bidx, pos].set(k_rope_new[:, 0, 0])
    # absorb: q_eff[h, r] = sum_k q_nope[h,k] wk_b[r,h,k]
    q_eff = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["wk_b"])
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = (jnp.einsum("bhr,bsr->bhs", q_eff.astype(jnp.float32),
                         c_cache.astype(jnp.float32)) +
              jnp.einsum("bhk,bsk->bhs", q_rope[:, 0].astype(jnp.float32),
                         r_cache.astype(jnp.float32))) * scale
    S = c_cache.shape[1]
    valid = jnp.arange(S)[None] <= pos[:, None]
    logits = jnp.where(valid[:, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", w, c_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhk->bhk", ctx.astype(x.dtype), p["wv_b"])
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    return out, MLACache(c_cache, r_cache)
