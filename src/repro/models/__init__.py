from .encdec import EncDec
from .lm import LM
from .model import build_model

__all__ = ["build_model", "LM", "EncDec"]
