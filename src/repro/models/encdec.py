"""Encoder-decoder backbone (whisper-large-v3).

The conv/mel frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings [B, S_enc, d_model].  Encoder layers are
bidirectional self-attention + GELU MLP with layernorm (pre-LN); decoder
layers add causal self-attention (cached at decode) and cross-attention to
the encoder output (K/V precomputed once per request).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..config import ModelConfig, ParallelConfig
from ..parallel.sharding import constrain, padded
from . import params as prm
from .attention import (KVCache, attn_spec, decode_attention, flash_or_ref,
                        project_qkv)
from .layers import (apply_embed, apply_mlp, apply_norm, apply_unembed,
                     embed_spec, learned_pos_spec, mlp_spec, norm_spec)


class EncDec:
    def __init__(self, cfg: ModelConfig, par: ParallelConfig | None = None,
                 mesh=None, rules=None, use_flash: bool = False):
        self.cfg = cfg
        self.par = par or ParallelConfig()
        self.mesh = mesh
        self.rules = rules
        self.use_flash = use_flash
        self.tp = 1 if mesh is None else mesh.shape.get("model", 1)
        self.vocab_padded = padded(cfg.vocab_size, self.tp * 128)

    # ------------------------------------------------------------ specs
    def param_spec(self) -> dict:
        cfg = self.cfg
        E, Dd = cfg.encoder_layers, cfg.num_layers
        enc = {
            "ln1": norm_spec(cfg, E),
            "attn": attn_spec(cfg, self.tp, E),
            "ln2": norm_spec(cfg, E),
            "mlp": mlp_spec(cfg, cfg.d_ff, E),
        }
        dec = {
            "ln1": norm_spec(cfg, Dd),
            "self_attn": attn_spec(cfg, self.tp, Dd),
            "ln_x": norm_spec(cfg, Dd),
            "cross_attn": attn_spec(cfg, self.tp, Dd),
            "ln2": norm_spec(cfg, Dd),
            "mlp": mlp_spec(cfg, cfg.d_ff, Dd),
        }
        return {
            "embed": embed_spec(cfg, self.vocab_padded),
            "dec_pos": learned_pos_spec(cfg, cfg.max_position),
            "enc_pos": learned_pos_spec(cfg, cfg.encoder_seq),
            "encoder": enc,
            "decoder": dec,
            "enc_norm": norm_spec(cfg),
            "final_norm": norm_spec(cfg),
        }

    def init(self, key: jax.Array) -> dict:
        return prm.init_tree(key, self.param_spec())

    def abstract_params(self) -> dict:
        return prm.abstract_tree(self.param_spec(), self.rules, self.mesh)

    def param_shardings(self) -> dict:
        return prm.shardings_tree(self.param_spec(), self.rules, self.mesh)

    # ------------------------------------------------------------ encoder
    def encode(self, params: dict, frames: jax.Array) -> jax.Array:
        """frames: [B, S_enc, d] stub embeddings -> encoder states."""
        cfg = self.cfg
        B, S, _ = frames.shape
        pe = params["enc_pos"]["pos_embedding"]
        npos = pe.shape[0]
        pos_emb = pe[jnp.arange(S) % npos]
        x = frames.astype(jnp.dtype(cfg.dtype)) + pos_emb.astype(
            jnp.dtype(cfg.dtype))
        x = constrain(x, ("batch", "seq", "act_embed"), self.rules, self.mesh)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        sp = ("seq_sp" if S % max(self.tp, 1) == 0 else "seq")

        def body(x, lp):
            h = apply_norm(lp["ln1"], x, cfg)
            q, k, v = project_qkv(lp["attn"], h, cfg, positions, rope=False)
            o = flash_or_ref(q, k, v, positions, positions, cross=True,
                             use_flash=False)
            x = x + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            h = apply_norm(lp["ln2"], x, cfg)
            x = x + apply_mlp(lp["mlp"], h, cfg)
            x = constrain(x, ("batch", sp, "act_embed"), self.rules,
                          self.mesh)
            return x, None

        if self.par.remat != "none":
            body = jax.checkpoint(body)
        if self.par.scan_layers:
            x, _ = jax.lax.scan(body, x, params["encoder"])
        else:
            E = cfg.encoder_layers
            for i in range(E):
                x, _ = body(x, jax.tree.map(lambda a: a[i], params["encoder"]))
        return apply_norm(params["enc_norm"], x, cfg)

    # ------------------------------------------------------------ decoder
    def _dec_positions(self, B: int, S: int, offset=0):
        return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32) + offset, (B, S))

    def decode_train(self, params: dict, tokens: jax.Array,
                     enc_out: jax.Array) -> jax.Array:
        """Teacher-forced decoder pass. Returns logits [B, S, V]."""
        cfg = self.cfg
        B, S = tokens.shape
        pe = params["dec_pos"]["pos_embedding"]
        x = apply_embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
        x = x + pe[jnp.arange(S) % pe.shape[0]].astype(x.dtype)
        positions = self._dec_positions(B, S)
        enc_pos = self._dec_positions(B, enc_out.shape[1])

        sp = ("seq_sp" if S % max(self.tp, 1) == 0 else "seq")

        def body(x, lp):
            h = apply_norm(lp["ln1"], x, cfg)
            q, k, v = project_qkv(lp["self_attn"], h, cfg, positions,
                                  rope=False)
            o = flash_or_ref(q, k, v, positions, positions,
                             use_flash=self.use_flash)
            x = x + jnp.einsum("bshk,hkd->bsd", o, lp["self_attn"]["wo"])
            h = apply_norm(lp["ln_x"], x, cfg)
            q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
            ek = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
            ev = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
            o = flash_or_ref(q, ek, ev, positions, enc_pos, cross=True)
            x = x + jnp.einsum("bshk,hkd->bsd", o, lp["cross_attn"]["wo"])
            h = apply_norm(lp["ln2"], x, cfg)
            x = x + apply_mlp(lp["mlp"], h, cfg)
            x = constrain(x, ("batch", sp, "act_embed"), self.rules,
                          self.mesh)
            return x, None

        if self.par.remat != "none":
            body = jax.checkpoint(body)
        if self.par.scan_layers:
            x, _ = jax.lax.scan(body, x, params["decoder"])
        else:
            for i in range(cfg.num_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[i], params["decoder"]))
        x = apply_norm(params["final_norm"], x, cfg)
        return apply_unembed(params["embed"], x, cfg)

    def apply(self, params: dict, tokens: jax.Array, frames: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
        enc = self.encode(params, frames)
        logits = self.decode_train(params, tokens, enc)
        return logits, jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------ serving
    class Cache(NamedTuple):
        self_kv: KVCache           # [L, B, Hkv, S, hd]
        cross_k: jax.Array         # [L, B, S_enc, Hkv, hd]
        cross_v: jax.Array

    def init_cache(self, params: dict, enc_out: jax.Array, max_seq: int
                   ) -> "EncDec.Cache":
        cfg = self.cfg
        B = enc_out.shape[0]
        hd = cfg.resolved_head_dim

        def per_layer(lp):
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
            return ck, cv

        ck, cv = jax.vmap(per_layer)(params["decoder"]) if self.par.scan_layers \
            else jax.tree.map(lambda *x: jnp.stack(x), *[
                per_layer(jax.tree.map(lambda a: a[i], params["decoder"]))
                for i in range(cfg.num_layers)])
        from .attention import effective_kv_heads
        nkv = effective_kv_heads(cfg, self.tp)
        kv = KVCache(
            k=jnp.zeros((cfg.num_layers, B, nkv, max_seq, hd), jnp.bfloat16),
            v=jnp.zeros((cfg.num_layers, B, nkv, max_seq, hd), jnp.bfloat16))
        return EncDec.Cache(self_kv=kv, cross_k=ck, cross_v=cv)

    def decode_step(self, params: dict, cache: "EncDec.Cache",
                    tokens: jax.Array, pos: jax.Array
                    ) -> tuple[jax.Array, "EncDec.Cache"]:
        cfg = self.cfg
        B = tokens.shape[0]
        pe = params["dec_pos"]["pos_embedding"]
        x = apply_embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
        x = x + pe[pos % pe.shape[0]][:, None].astype(x.dtype)

        def body(x, inp):
            lp, kv, ck, cv = inp
            h = apply_norm(lp["ln1"], x, cfg)
            h, kv = decode_attention(lp["self_attn"], h, cfg, kv, pos)
            x = x + h
            h = apply_norm(lp["ln_x"], x, cfg)
            q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
            o = flash_or_ref(q, ck, cv, None, None, cross=True)
            x = x + jnp.einsum("bshk,hkd->bsd", o, lp["cross_attn"]["wo"])
            h = apply_norm(lp["ln2"], x, cfg)
            x = x + apply_mlp(lp["mlp"], h, cfg)
            return x, kv

        if self.par.scan_layers:
            x, new_kv = jax.lax.scan(
                body, x, (params["decoder"], cache.self_kv, cache.cross_k,
                          cache.cross_v))
        else:
            kvs = []
            for i in range(cfg.num_layers):
                sl = jax.tree.map(lambda a: a[i],
                                  (params["decoder"], cache.self_kv,
                                   cache.cross_k, cache.cross_v))
                x, kv = body(x, sl)
                kvs.append(kv)
            new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = apply_unembed(params["embed"], x, cfg)
        return logits, EncDec.Cache(self_kv=new_kv, cross_k=cache.cross_k,
                                    cross_v=cache.cross_v)
