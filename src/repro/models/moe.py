"""Mixture-of-Experts FFN with true expert-parallel all-to-all dispatch.

Why not GSPMD-sharded scatter dispatch: data-dependent scatter indices force
the partitioner to replicate the dispatch buffers (we measured 415 GiB/device
on granite-moe at batch 256 x 4096).  Production MoE systems (DeepSpeed-MoE,
NCCL-based EP) instead run the dispatch *locally* per data shard and exchange
tokens with all-to-all over the expert-parallel axis.  We do the same under a
fully-manual `shard_map`:

  1. route locally (router weights replicated),
  2. bucket tokens by destination EP rank into [ep, C_send, d] (sort-based
     rank-in-bucket, capacity drop),
  3. `lax.all_to_all` over the 'model' axis  (NOT ring traffic — see DESIGN.md
     §Arch-applicability: Symphony is transparent to a2a, paper §5),
  4. local scatter to [E_local, C_local, d], batched expert matmul,
  5. reverse a2a, weighted combine at the source.

Long sequences are processed in `dispatch_chunk`-token chunks (lax.scan) to
bound the a2a buffers.  On a 1-device mesh every step degenerates to local
compute, which the unit tests exploit against a dense reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..config import ModelConfig
from .params import ParamSpec

DISPATCH_CHUNK = 8192   # tokens per a2a round (bounds buffer memory)


def moe_spec(cfg: ModelConfig, layers: int | None = None) -> dict:
    m = cfg.moe
    d = cfg.d_model
    lead = (layers,) if layers is not None else ()
    la = ("layers",) if layers is not None else ()
    n_in = 2 if cfg.activation == "swiglu" else 1
    spec = {
        "router": ParamSpec(lead + (d, m.num_experts), la + ("embed", "experts"),
                            init="normal", scale=0.02, dtype=jnp.float32),
        "wi": ParamSpec(lead + (m.num_experts, d, n_in, m.d_ff_expert),
                        la + ("experts", "embed", None, "expert_mlp")),
        "wo": ParamSpec(lead + (m.num_experts, m.d_ff_expert, d),
                        la + ("experts", "expert_mlp", "embed")),
    }
    if m.shared_expert_d_ff:
        spec["shared_wi"] = ParamSpec(
            lead + (d, n_in, m.shared_expert_d_ff), la + ("embed", None, "mlp"))
        spec["shared_wo"] = ParamSpec(
            lead + (m.shared_expert_d_ff, d), la + ("mlp", "embed"))
    return spec


def _expert_ffn(wi, wo, x, activation: str) -> jax.Array:
    """x: [E_loc, C, d] -> [E_loc, C, d].  Activations stay in the input
    dtype (bf16): an fp32 upcast here materializes multi-GiB expert buffers
    (measured 2.9 GiB per [C, 2*d_ff] tensor on jamba)."""
    h = jnp.einsum("ecd,edif->ecif", x, wi)
    if activation == "swiglu":
        g, u = h[..., 0, :], h[..., 1, :]
        a = jax.nn.silu(g) * u
    else:
        a = h[..., 0, :]
        a = jnp.square(jax.nn.relu(a)) if activation == "relu2" \
            else jax.nn.gelu(a)
    return jnp.einsum("ecf,efd->ecd", a, wo)


def _rank_in_bucket(bucket_ids: jax.Array, n_buckets: int) -> jax.Array:
    """rank[i] = #(j < i with bucket_ids[j] == bucket_ids[i]) via sort."""
    n = bucket_ids.shape[0]
    order = jnp.argsort(bucket_ids)                    # stable
    sorted_b = bucket_ids[order]
    start = jnp.searchsorted(sorted_b, jnp.arange(n_buckets))
    rank_sorted = jnp.arange(n) - start[sorted_b]
    return jnp.zeros(n, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))


def _moe_chunk(xt, router, wi_loc, wo_loc, cfg, ep: int, has_a2a: bool):
    """One dispatch chunk. xt: [T, d] local tokens. Returns (y, aux)."""
    m = cfg.moe
    T, d = xt.shape
    k = m.experts_per_token
    E = m.num_experts
    E_loc = E // ep

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(0)
    ce = jnp.zeros(E).at[expert_idx.reshape(-1)].add(1.0) / (T * k)
    aux = (me * ce).sum() * E * m.aux_loss_coef

    flat_e = expert_idx.reshape(-1)                            # [T*k]
    src_tok = jnp.repeat(jnp.arange(T), k)
    dest = flat_e // E_loc                                     # EP rank
    C_send = int(np.ceil(T * k / ep * m.capacity_factor))
    rank = _rank_in_bucket(dest, ep)
    keep = rank < C_send
    d_idx = jnp.where(keep, dest, ep - 1)
    r_idx = jnp.where(keep, rank, C_send - 1)

    send_x = jnp.zeros((ep, C_send, d), xt.dtype).at[d_idx, r_idx].add(
        jnp.where(keep[:, None], xt[src_tok], 0.0), mode="drop")
    send_e = jnp.full((ep, C_send), -1, jnp.int32).at[d_idx, r_idx].max(
        jnp.where(keep, flat_e % E_loc, -1), mode="drop")

    if has_a2a:
        recv_x = jax.lax.all_to_all(send_x, "model", split_axis=0,
                                    concat_axis=0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, "model", split_axis=0,
                                    concat_axis=0, tiled=False)
    else:
        recv_x, recv_e = send_x, send_e

    # local dispatch to experts
    rx = recv_x.reshape(ep * C_send, d)
    re = recv_e.reshape(ep * C_send)
    # C_send already carries the capacity slack; the local buffer only needs
    # mild imbalance headroom across the E_loc experts of this rank.
    C_loc = int(np.ceil(ep * C_send / max(E_loc, 1) * m.capacity_factor)) \
        if E_loc > 1 else ep * C_send
    er = _rank_in_bucket(jnp.where(re >= 0, re, E_loc), E_loc + 1)
    ekeep = (re >= 0) & (er < C_loc)
    e_idx = jnp.where(ekeep, re, E_loc - 1)
    c_idx = jnp.where(ekeep, er, C_loc - 1)
    buf = jnp.zeros((E_loc, C_loc, d), xt.dtype).at[e_idx, c_idx].add(
        jnp.where(ekeep[:, None], rx, 0.0), mode="drop")

    out_buf = _expert_ffn(wi_loc, wo_loc, buf, cfg.activation)

    back = out_buf[e_idx, c_idx] * ekeep[:, None]              # [ep*C_send, d]
    back = back.reshape(ep, C_send, d)
    if has_a2a:
        back = jax.lax.all_to_all(back, "model", split_axis=0,
                                  concat_axis=0, tiled=False)

    gathered = back[d_idx, r_idx] * keep[:, None]              # [T*k, d]
    w = gate_vals.reshape(-1)[:, None].astype(xt.dtype)
    y = jnp.zeros((T, d), xt.dtype).at[src_tok].add(gathered * w)
    return y, aux


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig, rules=None, mesh=None
              ) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] (GSPMD-sharded). Returns (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape

    if mesh is None or mesh.size == 1:
        y, aux = _moe_tokens(x.reshape(-1, d), p["router"], p["wi"], p["wo"],
                             cfg, ep=1, has_a2a=False)
        y = y.reshape(B, S, d)
    else:
        manual = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        # shard the batch over whatever data axes divide it (long-context
        # decode has B=1: tokens then replicate over data, a2a still over EP)
        batch_ax = []
        nb = 1
        for a in ("pod", "data"):
            if a in mesh.shape and B % (nb * mesh.shape[a]) == 0:
                batch_ax.append(a)
                nb *= mesh.shape[a]
        batch_ax = tuple(batch_ax)
        ep = mesh.shape.get("model", 1)
        # tokens must also shard over the EP axis (sequence split), else all
        # ep ranks duplicate the routing/dispatch compute 16x (measured:
        # MODEL/HLO flops ratio 0.04 before this fix).  Decode steps (S==1)
        # keep replication — B_loc tokens are too few to split.
        seq_ax = "model" if S % max(ep, 1) == 0 and S >= ep else None

        def local(xl, router, wi_loc, wo_loc):
            bl, sl = xl.shape[0], xl.shape[1]
            y, aux = _moe_tokens(xl.reshape(bl * sl, d), router, wi_loc,
                                 wo_loc, cfg, ep=ep, has_a2a=ep > 1)
            return y.reshape(bl, sl, d), jax.lax.pmean(aux, manual)

        from ..compat import shard_map
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(batch_ax if batch_ax else None, seq_ax, None), P(),
                      P("model"), P("model")),
            out_specs=(P(batch_ax if batch_ax else None, seq_ax, None), P()))
        y, aux = fn(x, p["router"], p["wi"], p["wo"])

    if m.shared_expert_d_ff:
        xt = x.reshape(-1, d)
        h = jnp.einsum("td,dif->tif", xt, p["shared_wi"])
        if cfg.activation == "swiglu":
            a = jax.nn.silu(h[..., 0, :].astype(jnp.float32)).astype(x.dtype) \
                * h[..., 1, :]
        else:
            a = jax.nn.gelu(h[..., 0, :])
        y = y + jnp.einsum("tf,fd->td", a, p["shared_wo"]).reshape(B, S, d)
    return y, aux


def _moe_tokens(xt, router, wi_loc, wo_loc, cfg, ep: int, has_a2a: bool):
    """Chunked driver over the token axis."""
    from .. import flags
    T, d = xt.shape
    chunk = T if flags.ROOFLINE_MODE else min(DISPATCH_CHUNK, T)
    if T % chunk:
        chunk = T   # irregular small inputs: single chunk
    if chunk == T:
        return _moe_chunk(xt, router, wi_loc, wo_loc, cfg, ep, has_a2a)
    xc = xt.reshape(T // chunk, chunk, d)

    def body(_, xck):
        y, aux = _moe_chunk(xck, router, wi_loc, wo_loc, cfg, ep, has_a2a)
        return None, (y, aux)

    _, (ys, auxs) = jax.lax.scan(body, None, xc)
    return ys.reshape(T, d), auxs.sum()
