"""Decoder-only LM assembly (dense / MoE / SSM / hybrid / VLM backbones).

Layers are stacked with `lax.scan` over *periods*: a period is the repeating
pattern of sub-layers (length 1 for homogeneous stacks; 8 for jamba's
1-attention-per-8 interleave with MoE on odd layers).  All parameters of one
period position carry a leading [n_groups] axis, so the HLO stays compact at
96 layers and remat policies apply per period.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig, ParallelConfig
from ..parallel.sharding import constrain, padded
from . import params as prm
from .attention import KVCache, attn_spec, attention_block, decode_attention
from .layers import (apply_embed, apply_mlp, apply_norm, apply_unembed,
                     embed_spec, mlp_spec, norm_spec)
from .mla import MLACache, mla_block, mla_decode, mla_spec
from .moe import moe_block, moe_spec
from .ssm import SSMCache, init_ssm_cache, ssm_block, ssm_decode, ssm_spec


class LM:
    """Functional model: param specs + apply functions, no state."""

    def __init__(self, cfg: ModelConfig, par: ParallelConfig | None = None,
                 mesh=None, rules=None, use_flash: bool = False,
                 use_ssd_kernel: bool = False):
        self.cfg = cfg
        self.par = par or ParallelConfig()
        self.mesh = mesh
        self.rules = rules
        self.use_flash = use_flash
        self.use_ssd_kernel = use_ssd_kernel
        self.tp = 1 if mesh is None else mesh.shape.get("model", 1)
        self.vocab_padded = padded(cfg.vocab_size, self.tp * 128)
        # period structure
        self.period = 1
        if cfg.attn_every:
            self.period = cfg.attn_every
        if cfg.moe is not None and cfg.moe_every > 1:
            self.period = int(np.lcm(self.period, cfg.moe_every))
        assert cfg.num_layers % self.period == 0, (cfg.num_layers, self.period)
        self.n_groups = cfg.num_layers // self.period

    # ------------------------------------------------------------ specs
    def _block_spec(self, pos_in_period: int) -> dict:
        cfg = self.cfg
        kind = cfg.layer_kind(pos_in_period)
        d: dict = {"ln1": norm_spec(cfg, self.n_groups)}
        if kind == "attn":
            if cfg.attention == "mla":
                d["attn"] = mla_spec(cfg, self.tp, self.n_groups)
            else:
                d["attn"] = attn_spec(cfg, self.tp, self.n_groups)
        else:
            d["ssm"] = ssm_spec(cfg, self.tp, self.n_groups)
        if cfg.d_ff or cfg.is_moe_layer(pos_in_period):
            d["ln2"] = norm_spec(cfg, self.n_groups)
            if cfg.is_moe_layer(pos_in_period):
                d["moe"] = moe_spec(cfg, self.n_groups)
            else:
                d["mlp"] = mlp_spec(cfg, cfg.d_ff, self.n_groups)
        return d

    def param_spec(self) -> dict:
        cfg = self.cfg
        tree: dict = {"embed": embed_spec(cfg, self.vocab_padded),
                      "final_norm": norm_spec(cfg)}
        for i in range(self.period):
            tree[f"block_{i}"] = self._block_spec(i)
        return tree

    def init(self, key: jax.Array) -> dict:
        return prm.init_tree(key, self.param_spec())

    def abstract_params(self) -> dict:
        return prm.abstract_tree(self.param_spec(), self.rules, self.mesh)

    def param_shardings(self) -> dict:
        return prm.shardings_tree(self.param_spec(), self.rules, self.mesh)

    # ------------------------------------------------------------ forward
    def _apply_block(self, bp: dict, i: int, x: jax.Array,
                     positions: jax.Array) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        kind = cfg.layer_kind(i)
        aux = jnp.zeros((), jnp.float32)
        h = apply_norm(bp["ln1"], x, cfg)
        if kind == "attn":
            if cfg.attention == "mla":
                h = mla_block(bp["attn"], h, cfg, positions, self.use_flash)
            else:
                h = attention_block(bp["attn"], h, cfg, positions,
                                    self.use_flash)
        else:
            h = ssm_block(bp["ssm"], h, cfg, self.use_ssd_kernel)
        x = x + h
        if "mlp" in bp or "moe" in bp:
            h = apply_norm(bp["ln2"], x, cfg)
            if "moe" in bp:
                h, aux = moe_block(bp["moe"], h, cfg, self.rules, self.mesh)
            else:
                h = apply_mlp(bp["mlp"], h, cfg)
            x = x + h
        x = constrain(x, ("batch", "seq", "act_embed"), self.rules, self.mesh)
        return x, aux

    def _stack(self, params: dict, x: jax.Array, positions: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
        remat = self.par.remat != "none"
        # residuals saved at remat boundaries are sequence-sharded over the
        # TP axis (Megatron-SP): 16x smaller checkpoints; XLA re-gathers
        # inside the group.
        sp_ok = x.shape[1] % (self.tp or 1) == 0 and x.shape[1] > 1

        # remat="full": checkpoint every sub-layer, so the group backward
        # recomputes one layer at a time (hybrid groups hold several MoE
        # layers whose recompute buffers must not coexist).
        block = self._apply_block
        if self.par.remat == "full" and self.period > 1:
            block = jax.checkpoint(block, static_argnums=(1,))

        def group_fn(x, gparams):
            aux = jnp.zeros((), jnp.float32)
            for i in range(self.period):
                bp = gparams[f"block_{i}"]
                x, a = block(bp, i, x, positions)
                aux = aux + a
            if sp_ok:
                x = constrain(x, ("batch", "seq_sp", "act_embed"),
                              self.rules, self.mesh)
            return x, aux

        if remat:
            group_fn = jax.checkpoint(group_fn,
                                      prevent_cse=not self.par.scan_layers)

        gtrees = {f"block_{i}": params[f"block_{i}"] for i in range(self.period)}
        if self.par.scan_layers and self.n_groups > 1:
            def scan_body(x, gp):
                x, aux = group_fn(x, gp)
                return x, aux
            x, auxs = jax.lax.scan(scan_body, x, gtrees)
            return x, auxs.sum()
        aux = jnp.zeros((), jnp.float32)
        for g in range(self.n_groups):
            gp = jax.tree.map(lambda a: a[g], gtrees)
            x, a = group_fn(x, gp)
            aux = aux + a
        return x, aux

    def apply(self, params: dict, tokens: jax.Array | None = None,
              positions: jax.Array | None = None,
              embeds: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array]:
        """Train/prefill forward.

        tokens: [B, S] int32 (or `embeds` [B, S, d] from a modality stub).
        positions: [B, S] (or [B, S, 3] for m-rope).  Returns (logits, aux).
        """
        cfg = self.cfg
        if embeds is None:
            x = apply_embed(params["embed"], tokens).astype(
                jnp.dtype(cfg.dtype))
        else:
            x = embeds.astype(jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = constrain(x, ("batch", "seq", "act_embed"), self.rules, self.mesh)
        x, aux = self._stack(params, x, positions)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = apply_unembed(params["embed"], x, cfg)
        logits = constrain(logits, ("batch", "seq", "act_heads"),
                           self.rules, self.mesh)
        return logits, aux

    # ------------------------------------------------------------ decode
    def init_cache(self, batch: int, max_seq: int) -> dict:
        """Per-period-position cache stacks with leading [n_groups] axis."""
        cfg = self.cfg
        caches: dict = {}
        hd = cfg.resolved_head_dim
        G = self.n_groups
        for i in range(self.period):
            kind = cfg.layer_kind(i)
            if kind == "attn":
                if cfg.attention == "mla":
                    m = cfg.mla
                    caches[f"block_{i}"] = MLACache(
                        c=jnp.zeros((G, batch, max_seq, m.kv_lora_rank),
                                    jnp.bfloat16),
                        k_rope=jnp.zeros((G, batch, max_seq, m.qk_rope_head_dim),
                                         jnp.bfloat16))
                else:
                    from .attention import effective_kv_heads
                    nkv = effective_kv_heads(cfg, self.tp)
                    s = min(max_seq, cfg.sliding_window) if cfg.sliding_window \
                        else max_seq
                    caches[f"block_{i}"] = KVCache(
                        k=jnp.zeros((G, batch, nkv, s, hd), jnp.bfloat16),
                        v=jnp.zeros((G, batch, nkv, s, hd), jnp.bfloat16))
            else:
                c = init_ssm_cache(cfg, batch, self.tp)
                caches[f"block_{i}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (G,) + a.shape), c,
                    is_leaf=lambda a: isinstance(a, jax.Array))
        return caches

    def decode_step(self, params: dict, cache: dict, tokens: jax.Array,
                    pos: jax.Array) -> tuple[jax.Array, dict]:
        """tokens: [B, 1]; pos: [B] absolute positions. Returns (logits, cache)."""
        cfg = self.cfg
        x = apply_embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
        x = constrain(x, ("batch", None, "act_embed"), self.rules, self.mesh)

        def step_group(x, inp):
            gparams, gcache = inp
            new_caches = {}
            for i in range(self.period):
                bp = gparams[f"block_{i}"]
                c = gcache[f"block_{i}"]
                kind = cfg.layer_kind(i)
                h = apply_norm(bp["ln1"], x, cfg)
                if kind == "attn":
                    if cfg.attention == "mla":
                        h, c = mla_decode(bp["attn"], h, cfg, c, pos)
                    else:
                        h, c = decode_attention(bp["attn"], h, cfg, c, pos)
                else:
                    h, c = ssm_decode(bp["ssm"], h, cfg, c)
                x = x + h
                if "mlp" in bp or "moe" in bp:
                    h = apply_norm(bp["ln2"], x, cfg)
                    if "moe" in bp:
                        h, _ = moe_block(bp["moe"], h, cfg, self.rules,
                                         self.mesh)
                    else:
                        h = apply_mlp(bp["mlp"], h, cfg)
                    x = x + h
                new_caches[f"block_{i}"] = c
            return x, new_caches

        gparams = {f"block_{i}": params[f"block_{i}"] for i in range(self.period)}
        if self.par.scan_layers and self.n_groups > 1:
            x, new_cache = jax.lax.scan(step_group, x, (gparams, cache))
        else:
            new_stack: list = []
            for g in range(self.n_groups):
                gp = jax.tree.map(lambda a: a[g], gparams)
                gc = jax.tree.map(lambda a: a[g], cache)
                x, nc = step_group(x, (gp, gc))
                new_stack.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_stack)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = apply_unembed(params["embed"], x, cfg)
        return logits, new_cache
