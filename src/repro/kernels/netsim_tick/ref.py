"""Golden reference for the fused netsim tick kernel.

The reference *is* the staged pure-XLA engine: the kernel body replays
the stage functions' op sequence, so equivalence is asserted tick-for-
tick (bitwise in ``segsum="scatter"`` interpret mode) against these.
"""
from __future__ import annotations

from ...core.netsim.stages import (engine_tick_xla, instance_view,
                                   stage_marking, stage_progress,
                                   stage_queues, stage_share, stage_starts,
                                   stage_symphony)
from .kernel import TickOut


def tick_ref(ctx, cfg, state, tick):
    """Whole-tick oracle: the staged XLA engine, ``(state', sample)``."""
    return engine_tick_xla(ctx, cfg, state, tick)


def window_ref(ctx, cfg, state, base_tick, n: int):
    """Oracle for `ops.engine_window_fused`: ``n`` staged-XLA ticks from
    ``base_tick``, returning the final state and the LAST tick's sample
    (the window kernel's contract)."""
    import jax
    import jax.numpy as jnp

    def body(st, t):
        return engine_tick_xla(ctx, cfg, st, t)

    ticks = base_tick + jnp.arange(n)
    state, samples = jax.lax.scan(body, state, ticks)
    return state, jax.tree.map(lambda x: x[-1], samples)


def fused_outputs_ref(ctx, cfg, starts, state, tick) -> TickOut:
    """Per-output oracle for `kernel.netsim_tick`: the same
    :class:`TickOut` assembled from the individual stage functions."""
    inst = instance_view(ctx, starts, state, cfg.mtu, cfg.per_step_ecmp)
    shr = stage_share(ctx, cfg, inst, tick)
    q, p_red = stage_queues(ctx, cfg, state.q, shr.offered)
    _lam, pkts, sm = stage_marking(ctx, cfg, state, inst, p_red, shr.eff,
                                   starts.lam, tick)
    _sent, _done, _finish, newly_done = stage_progress(
        ctx, cfg, state, inst, starts.step_of, shr.eff, tick)
    stepmin, s_psnwin, s_alpha, s_cnt, s_cntop = stage_symphony(
        ctx, cfg, state, inst, sm, pkts, newly_done, shr.eff, tick)
    return TickOut(iroute=inst.iroute, eff=shr.eff, offered=shr.offered,
                   q=q, p_red=p_red, s_stepmin=stepmin, s_psnwin=s_psnwin,
                   s_alpha=s_alpha, s_cnt=s_cnt, s_cntop=s_cntop)
