"""Multi-tick window kernel: ``tick_window`` engine ticks per pallas call.

The per-tick path (`ops.engine_tick_fused`) round-trips every piece of
engine state through HBM once per tick: the kernel reads link queues /
Symphony windows / instance slots, writes them back, and the XLA-side
cold stages read them again.  This kernel instead fuses a *window* of
``n`` consecutive ticks into ONE ``pl.pallas_call``: the full engine
state is read once, carried through an in-kernel ``lax.fori_loop``
(state lives in registers/VMEM between ticks), and written back once —
amortizing the state HBM traffic by ``1/tick_window`` (see
``benchmarks/roofline.py``).

Each loop iteration replays the *entire* engine tick — ``stage_starts``,
the fused hot stages (`kernel.hot_tick`, the same value-level body the
single-tick kernel runs), and the cold composition (`ops.compose_tick`:
marking, progress, rate control, segment barriers, metrics) — by
rebuilding the `EngineCtx` / `EngineParams` views from the kernel's
refs, so the tick semantics are *definitionally* those of the staged
engine; equivalence is pinned in tests/test_netsim_tick_kernel.py.

Outputs are the post-window `EngineState` plus the metric sample of the
window's **last** tick, matching the simulator's record-period contract
(`simulator._core_impl` samples the last tick of each record period, so
windows are aligned to divide the period).

Scope: the window kernel keeps the whole ``[FW]`` instance axis — and
the packed per-instance route/chunk/ECMP tables (`params.PackedTables`)
— VMEM-resident across the in-kernel ``fori_loop``, so table reads cost
their one initial DMA per *window*, not per tick.  With ``blk`` set the
tiling normalizes away here (``params.plan_tiling`` returns ``None``
for ``tick_window > 1``): windowing already amortizes the state traffic
the tiling would stream.  The kernel is exercised in interpret mode on
CPU; the cold stages it replays contain gathers/scatters that Mosaic
cannot lower today, so the Mosaic-readiness CI gate covers the tiled
single-tick kernel only.

The carried engine state is donated: the pallas call aliases each of
the ``N_STATE`` state inputs to its same-shaped state output
(``input_output_aliases``), so a record period of windows updates the
state buffers in place instead of copying them once per window.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.netsim.params import (PackedTables, RuntimeKnobs, SimStructure,
                                   SymphonyParams, merge_params,
                                   pack_route_tables)
from ...core.netsim.stages import EngineState, WLArrays, make_ctx, stage_starts
from .kernel import hot_tick

N_STATE = len(EngineState._fields)   # 20
N_WL = len(WLArrays._fields)         # 15
N_STATIC = 12                        # simulator.Static fields
N_TABLES = len(PackedTables._fields)  # 6 packed route-table operands
# Static fields that are scalars (marshalled as shape-(1,) operands):
_STATIC_SCALARS = (8, 9, 11)         # bg_period_ticks, bg_duty, seed


def _window_kernel(*refs, struct: SimStructure, n: int, policy: str,
                   segsum: str):
    from ...core.netsim.simulator import Static
    from .ops import compose_tick

    base = N_STATE + N_WL + N_STATIC
    ins = refs[:base + N_TABLES + 2]
    outs = refs[base + N_TABLES + 2:]

    state = EngineState(*(r[...] for r in ins[:N_STATE]))
    wl = WLArrays(*(r[...] for r in ins[N_STATE:N_STATE + N_WL]))
    sa = [r[...] for r in ins[N_STATE + N_WL:base]]
    for i in _STATIC_SCALARS:        # back to true scalars for broadcasting
        sa[i] = sa[i][0]
    st = Static(*sa)
    # packed route tables: read once, VMEM-resident across the fori_loop
    tables = PackedTables(*(r[...] for r in ins[base:base + N_TABLES]))
    ki = ins[base + N_TABLES]
    kf = ins[base + N_TABLES + 1]

    base_tick = ki[0]
    knobs = RuntimeKnobs(
        red_kmin=kf[0], red_kmax=kf[1], red_pmax=kf[2],
        cc_epoch_ticks=ki[1], cc_g=kf[3], cc_rai=kf[4], cc_rhai=kf[5],
        cc_fr_stages=ki[2], cc_min_rate=kf[6],
        sym_on=ki[3],
        sym=SymphonyParams(k=kf[7], tau=kf[8], n_warmup=kf[9],
                           n_sample=kf[10], alpha_max=kf[11]),
        sym_win_ticks=ki[4], sym_start_tick=ki[5], pq_on=ki[6])
    cfg = merge_params(struct, knobs)
    ctx = make_ctx(st, wl, struct.window, tables=tables)
    SEG = int(wl.chunk_sched.shape[1])
    J = ctx.J
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    i32 = lambda v: jnp.asarray(v, jnp.int32)

    def one_tick(state, tick):
        starts = stage_starts(ctx, state, tick)
        out = hot_tick(
            starts.step_of.reshape(ctx.FW), starts.sent.reshape(ctx.FW),
            starts.rate.reshape(ctx.FW), state.done_upto, state.q,
            state.s_stepmin, state.s_psnwin, state.s_alpha,
            state.s_cnt, state.s_cntop,
            st.routes, st.path_table, st.n_paths, st.cap, st.link_dom,
            st.bg_base, st.bg_amp,
            ctx.inst_job, ctx.inst_flow, ctx.sps_i, ctx.phase_i, ctx.nph_i,
            ctx.off_i, wl.chunk_sched,
            i32(tick), i32(st.seed), i32(st.bg_period_ticks),
            i32(cfg.sym_win_ticks), i32(cfg.pq_on),
            f32(st.bg_duty), f32(cfg.red_kmin), f32(cfg.red_kmax),
            f32(cfg.red_pmax), f32(cfg.sym.tau), f32(cfg.sym.n_sample),
            f32(cfg.sym.alpha_max),
            H=ctx.H, SEG=SEG, dt=cfg.dt, mtu=cfg.mtu,
            per_step_ecmp=cfg.per_step_ecmp, policy=policy, segsum=segsum,
            tables=ctx.tables)
        return compose_tick(ctx, cfg, state, tick, starts, out)

    zero_sample = (jnp.zeros(J, jnp.int32), jnp.zeros(J, jnp.int32),
                   jnp.zeros(J, jnp.int32), jnp.zeros(J, jnp.float32),
                   jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))

    def body(t, carry):
        state, _ = carry
        return one_tick(state, base_tick + t)

    state, sample = jax.lax.fori_loop(0, n, body, (state, zero_sample))

    for r, v in zip(outs[:N_STATE], state):
        r[...] = v
    minw, maxw, dmin, tput, qmax, amax = sample
    outs[N_STATE][...] = minw
    outs[N_STATE + 1][...] = maxw
    outs[N_STATE + 2][...] = dmin
    outs[N_STATE + 3][...] = tput
    outs[N_STATE + 4][0] = qmax
    outs[N_STATE + 5][0] = amax


def netsim_window(ctx, cfg, state: EngineState, base_tick, n: int, *,
                  policy: str, segsum: str, interpret: bool):
    """Dispatch ``n`` ticks starting at ``base_tick`` as one kernel call.

    Returns ``(state after n ticks, metric sample of tick base_tick+n-1)``
    with the exact `stages.engine_tick` sample/state contract.
    """
    st, wl = ctx.st, ctx.wl
    i32 = lambda v: jnp.asarray(v, jnp.int32)
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    struct = SimStructure(
        dt=cfg.dt, n_ticks=cfg.n_ticks, window=cfg.window, mtu=cfg.mtu,
        record_every=cfg.record_every, share_policy=cfg.share_policy,
        deploy=cfg.deploy, per_step_ecmp=cfg.per_step_ecmp,
        backend=cfg.backend, segsum=cfg.segsum, blk=cfg.blk,
        tick_window=cfg.tick_window)
    ki = jnp.stack([i32(base_tick), i32(cfg.cc_epoch_ticks),
                    i32(cfg.cc_fr_stages), i32(cfg.sym_on),
                    i32(cfg.sym_win_ticks), i32(cfg.sym_start_tick),
                    i32(cfg.pq_on)])
    kf = jnp.stack([f32(cfg.red_kmin), f32(cfg.red_kmax), f32(cfg.red_pmax),
                    f32(cfg.cc_g), f32(cfg.cc_rai), f32(cfg.cc_rhai),
                    f32(cfg.cc_min_rate), f32(cfg.sym.k), f32(cfg.sym.tau),
                    f32(cfg.sym.n_warmup), f32(cfg.sym.n_sample),
                    f32(cfg.sym.alpha_max)])
    sa = list(st)
    for i in _STATIC_SCALARS:
        sa[i] = sa[i].reshape(1)
    tables = ctx.tables if getattr(ctx, "tables", None) is not None \
        else pack_route_tables(st, wl, cfg.window)
    operands = list(state) + list(wl) + sa + list(tables) + [ki, kf]

    J = ctx.J
    out_shape = ([jax.ShapeDtypeStruct(x.shape, x.dtype) for x in state]
                 + [jax.ShapeDtypeStruct((J,), jnp.int32)] * 3
                 + [jax.ShapeDtypeStruct((J,), jnp.float32),
                    jax.ShapeDtypeStruct((1,), jnp.float32),
                    jax.ShapeDtypeStruct((1,), jnp.float32)])
    outs = pl.pallas_call(
        partial(_window_kernel, struct=struct, n=int(n), policy=policy,
                segsum=segsum),
        out_shape=out_shape,
        # state operand i writes state output i (same shape/dtype): donate
        # the carried buffers so chained windows update state in place
        # instead of copying all N_STATE arrays once per window.
        input_output_aliases={i: i for i in range(N_STATE)},
        interpret=interpret,
    )(*operands)
    new_state = EngineState(*outs[:N_STATE])
    sample = (outs[N_STATE], outs[N_STATE + 1], outs[N_STATE + 2],
              outs[N_STATE + 3], outs[N_STATE + 4][0], outs[N_STATE + 5][0])
    return new_state, sample
