"""Fused netsim tick hot path as a Pallas kernel.

The staged XLA engine (`core/netsim/stages.py`) runs each hot stage of a
tick — route gather, per-link scatter-add bandwidth sharing, queue/RED
integration, Symphony per-(domain, job) scatter — as a separate XLA op
with its own HBM round trip.  This kernel fuses them into one program:
the per-instance view, both share classes, the link queues, and the
Symphony state block updates are computed with everything resident
on-chip, and only the tick's true inputs/outputs touch HBM.

The stage functions stay the golden reference (`ref.py`): the kernel body
replays their op sequence exactly, so in interpret mode the fused tick is
**bit-for-bit** identical to the staged engine — the seed golden chain
(Table-1 finish-tick traces) holds under ``backend="pallas"``.

Share policies: ``proportional`` and ``pq`` are implemented in-kernel
(both classes are computed and the traced ``pq_on`` gate selects, exactly
like the XLA path's ``lax.cond``-under-vmap select); ``wfq``/``drr`` stay
on the XLA path behind `stages.resolve_backend`.

Segment reductions come in two flavors (``segsum=``):

* ``"scatter"`` — `.at[].add/max/min`, the reference op sequence;
  bitwise-equal to the staged engine (interpret mode).
* ``"onehot"``  — dense one-hot contractions (MXU matmul for the adds,
  masked row reductions for min/max).  Mosaic has no vector scatter, so
  this is the shape a compiled TPU lowering takes; adds reassociate, so
  it is allclose-not-bitwise vs the reference.

Tiling (``blk=``): the onehot variant additionally runs as a proper grid
kernel over the flat ``[FW]`` instance axis — ``grid = (4 sweeps,
ceil(FW/blk) blocks)`` with ``BlockSpec``-tiled per-instance operands, so
the dense one-hot contraction is ``[L+1, blk*H]`` per block instead of
``[L+1, FW*H]`` and the working set fits VMEM at any instance count.
The tick's chained global reductions (job min-wire -> link scales ->
eff -> Symphony step-min -> psn-window) force multiple passes over the
instance blocks; each pass is one sweep of the grid, with the ``[J]`` /
``[L+1]`` / ``[DJ]`` reductions accumulated as per-block partials in
persistent scratch:

  sweep 0   job min-wire partials + proportional offered-load partials
  sweep 1   hi/lo-class offered-load partials (needs complete min-wire)
  sweep 2   link scales finalized (block 0), then per-block eff +
            Symphony cnt/cntop/step-min partials
  sweep 3   step-min finalized (block 0), per-block psn-window partials,
            per-instance outputs; final block flushes link/Symphony outs

min/max reductions accumulate exactly (associative), so integer outputs
match the untiled kernel bit-for-bit; the float adds reassociate across
blocks (allclose), same contract as ``onehot`` itself.  Non-dividing
``FW`` is edge-padded to a whole number of blocks and the padded rows
masked inactive.  Under ``jax.vmap`` (the grid executor's lane batching)
the whole thing stays ONE ``pallas_call`` with a leading lane axis
prepended to the grid: ``[lanes, 4, FW_blocks]``.

Gather-free tiling: the tiled kernel takes no index-table operands at
all.  Every former gather — ``routes[inst_flow]``, the per-step ECMP
candidate lookup, ``chunk_sched[inst_job]``, ``done_upto[inst_flow]`` —
is replaced by *packed per-instance tables* (`params.pack_route_tables`)
streamed block-by-block through the same BlockSpec pipeline as the
instance state, plus iota-select-and-sum reads (`_onehot_take` /
`_onehot_col`: exactly one selected entry per output, so the masked sum
is value-exact) for the in-kernel dynamic lookups (ECMP candidate
choice, per-link scales, Symphony rows).  Per-block valid-row counts
ride in scalar prefetch (``PrefetchScalarGridSpec``), so block shapes
stay static and the next block's table DMA overlaps compute.  The
resulting TPU-platform StableHLO contains **zero** ``stablehlo.gather``
and **zero** ``stablehlo.scatter`` ops — the full Mosaic-lowerable
shape, CI-gated.

Compiled (non-interpret) execution is untested on this repo's CPU-only
CI — `ops.use_interpret` defaults to interpret mode on CPU hosts.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.netsim.stages import WIRE_SEG, per_hop

# stages.BIG as a Python int: the kernel body must not capture device
# constants (pallas requires all array operands to be explicit inputs).
_BIG = 2**30

SEGSUM_MODES = ("scatter", "onehot")

# sweeps of the tiled grid (see module docstring)
TILED_SWEEPS = 4


class TickOut(NamedTuple):
    """Fused-kernel outputs: everything the XLA-side stages still need."""
    iroute: jax.Array     # [FW, H]  selected per-instance routes
    eff: jax.Array        # [FW]     delivered bytes/s per instance
    offered: jax.Array    # [L+1]    offered load per link
    q: jax.Array          # [L+1]    integrated queues
    p_red: jax.Array      # [L+1]    RED marking profile
    s_stepmin: jax.Array  # [DJ]     Symphony state block (post-update)
    s_psnwin: jax.Array
    s_alpha: jax.Array
    s_cnt: jax.Array
    s_cntop: jax.Array


# ------------------------------------------------- segment reductions
def _rows(n: int, m: int) -> jax.Array:
    return jax.lax.broadcasted_iota(jnp.int32, (n, m), 0)


def _segadd(base, idx, vals, mode):
    """``base.at[idx].add(vals)``; dense mode uses a one-hot contraction
    (MXU-friendly, reassociates the adds — allclose, not bitwise)."""
    if mode == "scatter":
        return base.at[idx].add(vals)
    oh = _rows(base.shape[0], idx.shape[0]) == idx[None, :]
    if jnp.issubdtype(vals.dtype, jnp.floating):
        return base + jnp.dot(oh.astype(vals.dtype), vals,
                              preferred_element_type=vals.dtype)
    return base + jnp.where(oh, vals[None, :], 0).sum(axis=1)


def _segmax(base, idx, vals, mode):
    if mode == "scatter":
        return base.at[idx].max(vals)
    neutral = (jnp.finfo(vals.dtype).min
               if jnp.issubdtype(vals.dtype, jnp.floating)
               else jnp.iinfo(vals.dtype).min)
    oh = _rows(base.shape[0], idx.shape[0]) == idx[None, :]
    return jnp.maximum(base, jnp.where(oh, vals[None, :], neutral).max(axis=1))


def _segmin(base, idx, vals, mode):
    if mode == "scatter":
        return base.at[idx].min(vals)
    neutral = (jnp.finfo(vals.dtype).max
               if jnp.issubdtype(vals.dtype, jnp.floating)
               else jnp.iinfo(vals.dtype).max)
    oh = _rows(base.shape[0], idx.shape[0]) == idx[None, :]
    return jnp.minimum(base, jnp.where(oh, vals[None, :], neutral).min(axis=1))


def _zero_null_link(q, L, mode):
    """``q.at[L].set(0.0)``: the trailing null link never queues.  The
    dense mode uses an iota select — bitwise-identical values (pure
    select, no arithmetic), but no scatter op for Mosaic to choke on."""
    if mode == "scatter":
        return q.at[L].set(0.0)
    return jnp.where(
        jax.lax.broadcasted_iota(jnp.int32, q.shape, 0) == L, 0.0, q)


# ----------------------------------------------- gather-free table reads
def _onehot_take(table, idx):
    """Gather-free ``table[idx]`` for a 1-D table: iota-select-and-sum
    over the table axis.  Exactly one entry is selected per output, so
    the masked sum is value-exact (``x + 0 == x``) — bitwise-equal to
    the gather for ints and for the non-negative floats used here."""
    flat = idx.reshape(-1)
    oh = _rows(table.shape[0], flat.shape[0]) == flat[None, :]
    out = jnp.where(oh, table[:, None], 0).sum(axis=0)
    return out.reshape(idx.shape)


def _onehot_col(table, idx):
    """Gather-free row-wise column select: ``table[arange(N), idx]`` for
    a ``[N, C]`` table and ``[N]`` indices.  Same exactness contract as
    :func:`_onehot_take`."""
    oh = (jax.lax.broadcasted_iota(jnp.int32, table.shape, 1)
          == idx[:, None])
    return jnp.where(oh, table, 0).sum(axis=1)


def _onehot_plane(table, idx):
    """Gather-free ``table[arange(N), idx, :]`` for a ``[N, P, H]``
    candidate slab and ``[N]`` choices: iota-select over the middle
    axis, exactly one plane selected per row (value-exact)."""
    N, P = table.shape[0], table.shape[1]
    oh = (jax.lax.broadcasted_iota(jnp.int32, (N, P), 1)
          == idx[:, None])[:, :, None]
    return jnp.where(oh, table, 0).sum(axis=1)


# ------------------------------------------------ value-level hot stages
def hot_tick(istep, isent, irate, done_upto, q_prev,
             s_stepmin, s_psnwin, s_alpha, s_cnt, s_cntop,
             routes, path_table, n_paths, cap, link_dom, bg_base, bg_amp,
             inst_job, inst_flow, sps, phase, nph, off, chunk_sched,
             tick, seed, bg_period, sym_win, pq_on,
             bg_duty, red_kmin, red_kmax, red_pmax, tau, n_sample, alpha_max,
             *, H, SEG, dt, mtu, per_step_ecmp, policy, segsum,
             tables=None) -> TickOut:
    """The fused hot stages on plain values (the monolithic kernel body,
    also replayed per tick by the multi-tick window kernel).  Op order
    replays the stage functions exactly — bitwise in scatter mode.

    With ``tables`` (a `params.PackedTables`) the per-flow/per-job table
    gathers become per-instance row reads and iota-selects; every
    replaced read is an int or exactly-one-nonzero select, so the
    bitwise contract is unchanged.  The multi-tick window kernel passes
    tables so they stay VMEM-resident across its ``fori_loop``.
    """
    J = chunk_sched.shape[0]
    DJ = s_stepmin.shape[0]
    L = cap.shape[0] - 1

    # ---- instance view (stages.instance_view, on-chip)
    iseg = (istep // sps) * nph + phase
    if tables is None:
        ichunk = chunk_sched[inst_job, jnp.clip(iseg, 0, SEG - 1)]
        done_i = done_upto[inst_flow]
    else:
        ichunk = _onehot_col(tables.chunk, jnp.clip(iseg, 0, SEG - 1))
        done_i = jnp.repeat(done_upto, istep.shape[0] // done_upto.shape[0])
    iwire = iseg * WIRE_SEG + istep % sps + off
    occupied = istep >= 0
    retired = occupied & (istep < done_i)
    complete = occupied & (isent >= ichunk)
    active = occupied & ~complete & ~retired
    ipsn = isent / mtu

    # ---- route selection (stages.select_routes)
    if per_step_ecmp:
        h = (inst_flow.astype(jnp.uint32) * jnp.uint32(2654435761)
             + jnp.maximum(istep, 0).astype(jnp.uint32) * jnp.uint32(40503)
             + (seed.astype(jnp.uint32) + 1) * jnp.uint32(2246822519))
        h = (h ^ (h >> 13)) * jnp.uint32(2654435761)
        h = h ^ (h >> 16)
        if tables is None:
            n_p = n_paths[inst_flow].astype(jnp.uint32)
        else:
            n_p = tables.n_paths.astype(jnp.uint32)
        choice = (h % n_p).astype(jnp.int32)
        if tables is None:
            iroute = path_table[inst_flow, choice]
            idom = link_dom[iroute]
        else:
            iroute = _onehot_plane(tables.cand, choice)
            idom = _onehot_plane(tables.cand_dom, choice)
    elif tables is None:
        iroute = routes[inst_flow]
        idom = link_dom[iroute]
    else:
        iroute = tables.routes
        idom = tables.route_dom
    flat_links = iroute.reshape(-1)

    def lsum(vals):
        return _segadd(jnp.zeros(L + 1, jnp.float32), flat_links,
                       per_hop(vals, H), segsum)

    # ---- bandwidth sharing (stages.share_proportional / share_pq)
    bg_on = (tick % bg_period).astype(jnp.float32) < \
        bg_duty * bg_period.astype(jnp.float32)
    bg = bg_base + jnp.where(bg_on, bg_amp, 0.0)
    w_rate = jnp.where(active, irate, 0.0)

    off_p = lsum(w_rate) + bg
    s_l = jnp.minimum(1.0, cap / jnp.maximum(off_p, 1.0))
    eff_p = w_rate * s_l[iroute].min(axis=1)

    job_min_wire = _segmin(jnp.full(J, _BIG, jnp.int32), inst_job,
                           jnp.where(active, iwire, _BIG), segsum)
    is_hi = active & (iwire <= job_min_wire[inst_job])
    hi_rate = jnp.where(is_hi, irate, 0.0)
    off_hi = lsum(hi_rate) + bg
    s_hi = jnp.minimum(1.0, cap / jnp.maximum(off_hi, 1.0))
    rem = jnp.maximum(cap - off_hi * s_hi, 0.0)
    lo_rate = jnp.where(active & ~is_hi, irate, 0.0)
    off_lo = lsum(lo_rate)
    s_lo = rem / jnp.maximum(off_lo, 1.0)
    share = jnp.where(is_hi[:, None], s_hi[iroute],
                      jnp.minimum(1.0, s_lo[iroute]))
    eff_q = w_rate * share.min(axis=1)
    off_q = off_hi + off_lo

    if policy == "pq":
        eff, offered = eff_q, off_q
    else:
        gate = pq_on != 0
        eff = jnp.where(gate, eff_q, eff_p)
        offered = jnp.where(gate, off_q, off_p)

    # ---- queues + RED (stages.stage_queues)
    q = jnp.maximum(q_prev + (offered - cap) * dt, 0.0)
    q = _zero_null_link(q, L, segsum)
    p_red = jnp.clip((q - red_kmin) / (red_kmax - red_kmin),
                     0.0, 1.0) * red_pmax

    # ---- Symphony per-(domain, job) scatter (stages.stage_symphony)
    dj = idom * J + inst_job[:, None]
    djf = dj.reshape(-1)
    sm = s_stepmin[dj]
    pkts = eff * dt / mtu
    newly_done = active & (isent + eff * dt >= ichunk)

    act4 = per_hop(active, H)
    send4 = per_hop(active & (eff > 1.0), H)
    done4 = per_hop(newly_done, H)
    wire4 = per_hop(iwire, H)
    psn4 = per_hop(ipsn + pkts, H)
    pkts4 = per_hop(pkts, H)
    sm4 = sm.reshape(-1)

    cnt = _segadd(s_cnt, djf, jnp.where(act4, pkts4, 0.0), segsum)
    cntop = _segadd(s_cntop, djf,
                    jnp.where(act4 & (wire4 > sm4), pkts4, 0.0), segsum)
    cand = _segmax(jnp.zeros(DJ, jnp.int32), djf,
                   jnp.where(done4, wire4 + 1, 0), segsum)
    cand = jnp.maximum(s_stepmin, cand)
    min_act = _segmin(jnp.full(DJ, _BIG, jnp.int32), djf,
                      jnp.where(act4 & ~done4, wire4, _BIG), segsum)
    stepmin = jnp.where(min_act < _BIG, jnp.minimum(cand, min_act), cand)
    psnwin = _segmax(s_psnwin, djf,
                     jnp.where(send4 & ~done4 & (wire4 == stepmin[djf]),
                               psn4, 0.0), segsum)

    sym_epoch = (tick % sym_win) == (sym_win - 1)
    have = cnt > n_sample
    exceed = cntop >= tau * cnt
    alpha_new = jnp.clip(
        s_alpha + jnp.where(exceed, 1.0, -1.0) * have,
        1.0, alpha_max)

    return TickOut(
        iroute=iroute, eff=eff, offered=offered, q=q, p_red=p_red,
        s_stepmin=stepmin,
        s_psnwin=jnp.where(sym_epoch, 0.0, psnwin),
        s_alpha=jnp.where(sym_epoch, alpha_new, s_alpha),
        s_cnt=jnp.where(sym_epoch, 0.0, cnt),
        s_cntop=jnp.where(sym_epoch, 0.0, cntop))


# ------------------------------------------------- monolithic kernel body
def _tick_kernel(step_ref, sent_ref, rate_ref, done_ref, q_ref,
                 smin_ref, spsn_ref, salpha_ref, scnt_ref, scntop_ref,
                 routes_ref, table_ref, npaths_ref, cap_ref, dom_ref,
                 bgb_ref, bga_ref,
                 job_ref, flow_ref, sps_ref, phase_ref, nph_ref, off_ref,
                 chunk_ref, iscal_ref, fscal_ref,
                 iroute_o, eff_o, offered_o, q_o, pred_o,
                 smin_o, spsn_o, salpha_o, scnt_o, scntop_o,
                 *, H, SEG, dt, mtu, per_step_ecmp, policy, segsum):
    out = hot_tick(
        step_ref[...], sent_ref[...], rate_ref[...], done_ref[...],
        q_ref[...], smin_ref[...], spsn_ref[...], salpha_ref[...],
        scnt_ref[...], scntop_ref[...],
        routes_ref[...], table_ref[...], npaths_ref[...], cap_ref[...],
        dom_ref[...], bgb_ref[...], bga_ref[...],
        job_ref[...], flow_ref[...], sps_ref[...], phase_ref[...],
        nph_ref[...], off_ref[...], chunk_ref[...],
        iscal_ref[0], iscal_ref[1], iscal_ref[2], iscal_ref[3], iscal_ref[4],
        fscal_ref[0], fscal_ref[1], fscal_ref[2], fscal_ref[3], fscal_ref[4],
        fscal_ref[5], fscal_ref[6],
        H=H, SEG=SEG, dt=dt, mtu=mtu, per_step_ecmp=per_step_ecmp,
        policy=policy, segsum=segsum)
    iroute_o[...] = out.iroute
    eff_o[...] = out.eff
    offered_o[...] = out.offered
    q_o[...] = out.q
    pred_o[...] = out.p_red
    smin_o[...] = out.s_stepmin
    spsn_o[...] = out.s_psnwin
    salpha_o[...] = out.s_alpha
    scnt_o[...] = out.s_cnt
    scntop_o[...] = out.s_cntop


# ----------------------------------------------------- tiled kernel body
def _tiled_tick_kernel(*refs, H, SEG, blk, dt, mtu, per_step_ecmp, policy):
    """One tick, tiled over the instance axis: grid = (sweep, block).

    Gather-free: per-instance refs — including the packed route/chunk/
    ECMP tables — hold one ``blk``-row block (BlockSpec-sliced); link/
    Symphony refs hold whole arrays; there are no index-table operands
    left to gather from.  ``refs[0]`` is the scalar-prefetch ref with
    the per-block valid-row counts (the only trace-time metadata the
    blocks need — keeping it lane-invariant is what lets ``vmap`` batch
    the lane axis into this one ``pallas_call``).  The scratch refs
    persist across grid steps and carry the cross-block partials.
    """
    nroute = 3 if per_step_ecmp else 2
    n_in = 20 + nroute + 2
    nvalid_ref = refs[0]
    ins = refs[1:1 + n_in]
    outs = refs[1 + n_in:1 + n_in + 10]
    (jobmin_s, offp_s, offhi_s, offlo_s, sl_s, shi_s, slo_s,
     cnt_s, cntop_s, cand_s, minact_s, stepmin_s, psnwin_s) = \
        refs[1 + n_in + 10:]

    (step_ref, sent_ref, rate_ref, done_ref,
     q_ref, smin_ref, spsn_ref, salpha_ref, scnt_ref, scntop_ref,
     cap_ref, bgb_ref, bga_ref,
     job_ref, flow_ref, sps_ref, phase_ref, nph_ref, off_ref,
     chunk_ref) = ins[:20]
    route_refs = ins[20:20 + nroute]
    iscal_ref, fscal_ref = ins[20 + nroute:]
    (iroute_o, eff_o, offered_o, q_o, pred_o,
     smin_o, spsn_o, salpha_o, scnt_o, scntop_o) = outs

    s = pl.program_id(0)
    b = pl.program_id(1)
    nb = pl.num_programs(1)

    istep = step_ref[...]
    isent = sent_ref[...]
    irate = rate_ref[...]
    inst_job = job_ref[...]
    inst_flow = flow_ref[...]
    sps = sps_ref[...]
    phase = phase_ref[...]
    nph = nph_ref[...]
    off = off_ref[...]
    cap = cap_ref[...]
    tick, seed = iscal_ref[0], iscal_ref[1]
    bg_period, sym_win, pq_on = iscal_ref[2], iscal_ref[3], iscal_ref[4]
    bg_duty = fscal_ref[0]
    red_kmin, red_kmax, red_pmax = fscal_ref[1], fscal_ref[2], fscal_ref[3]
    tau, n_sample, alpha_max = fscal_ref[4], fscal_ref[5], fscal_ref[6]
    J = jobmin_s.shape[0]
    DJ = smin_ref.shape[0]
    L = cap.shape[0] - 1

    # ---- per-block instance view; edge-padded rows are masked inactive
    valid = jax.lax.broadcasted_iota(jnp.int32, (blk,), 0) < nvalid_ref[b]
    iseg = (istep // sps) * nph + phase
    ichunk = _onehot_col(chunk_ref[...], jnp.clip(iseg, 0, SEG - 1))
    iwire = iseg * WIRE_SEG + istep % sps + off
    occupied = istep >= 0
    retired = occupied & (istep < done_ref[...])
    complete = occupied & (isent >= ichunk)
    active = occupied & ~complete & ~retired & valid
    ipsn = isent / mtu

    if per_step_ecmp:
        cand_ref, cdom_ref, npaths_ref = route_refs
        h = (inst_flow.astype(jnp.uint32) * jnp.uint32(2654435761)
             + jnp.maximum(istep, 0).astype(jnp.uint32) * jnp.uint32(40503)
             + (seed.astype(jnp.uint32) + 1) * jnp.uint32(2246822519))
        h = (h ^ (h >> 13)) * jnp.uint32(2654435761)
        h = h ^ (h >> 16)
        n_p = npaths_ref[...].astype(jnp.uint32)
        choice = (h % n_p).astype(jnp.int32)
        iroute = _onehot_plane(cand_ref[...], choice)
        idom = _onehot_plane(cdom_ref[...], choice)
    else:
        routes_ref, rdom_ref = route_refs
        iroute = routes_ref[...]
        idom = rdom_ref[...]
    flat_links = iroute.reshape(-1)
    w_rate = jnp.where(active, irate, 0.0)

    bg_on = (tick % bg_period).astype(jnp.float32) < \
        bg_duty * bg_period.astype(jnp.float32)
    bg = bgb_ref[...] + jnp.where(bg_on, bga_ref[...], 0.0)

    def block_lsum(acc, vals):
        return _segadd(acc, flat_links, per_hop(vals, H), "onehot")

    @pl.when((s == 0) & (b == 0))
    def _init():
        jobmin_s[...] = jnp.full(J, _BIG, jnp.int32)
        offp_s[...] = jnp.zeros(L + 1, jnp.float32)
        offhi_s[...] = jnp.zeros(L + 1, jnp.float32)
        offlo_s[...] = jnp.zeros(L + 1, jnp.float32)
        cnt_s[...] = jnp.zeros(DJ, jnp.float32)
        cntop_s[...] = jnp.zeros(DJ, jnp.float32)
        cand_s[...] = jnp.zeros(DJ, jnp.int32)
        minact_s[...] = jnp.full(DJ, _BIG, jnp.int32)
        psnwin_s[...] = jnp.zeros(DJ, jnp.float32)

    # ---- sweep 0: job min-wire + proportional offered-load partials
    @pl.when(s == 0)
    def _sweep0():
        jobmin_s[...] = _segmin(jobmin_s[...], inst_job,
                                jnp.where(active, iwire, _BIG), "onehot")
        offp_s[...] = block_lsum(offp_s[...], w_rate)

    # ---- sweep 1: hi/lo-class offered partials (min-wire now complete)
    @pl.when(s == 1)
    def _sweep1():
        is_hi = active & (iwire <= _onehot_take(jobmin_s[...], inst_job))
        offhi_s[...] = block_lsum(offhi_s[...], jnp.where(is_hi, irate, 0.0))
        offlo_s[...] = block_lsum(offlo_s[...],
                                  jnp.where(active & ~is_hi, irate, 0.0))

    # ---- sweep 2, first block: finalize the per-link scale factors
    @pl.when((s == 2) & (b == 0))
    def _scales():
        off_p = offp_s[...] + bg
        sl_s[...] = jnp.minimum(1.0, cap / jnp.maximum(off_p, 1.0))
        off_hi = offhi_s[...] + bg
        s_hi = jnp.minimum(1.0, cap / jnp.maximum(off_hi, 1.0))
        shi_s[...] = s_hi
        rem = jnp.maximum(cap - off_hi * s_hi, 0.0)
        slo_s[...] = rem / jnp.maximum(offlo_s[...], 1.0)

    def eff_block():
        is_hi = active & (iwire <= _onehot_take(jobmin_s[...], inst_job))
        eff_p = w_rate * _onehot_take(sl_s[...], iroute).min(axis=1)
        share = jnp.where(is_hi[:, None], _onehot_take(shi_s[...], iroute),
                          jnp.minimum(1.0, _onehot_take(slo_s[...], iroute)))
        eff_q = w_rate * share.min(axis=1)
        if policy == "pq":
            return eff_q
        return jnp.where(pq_on != 0, eff_q, eff_p)

    def dj_block():
        dj = idom * J + inst_job[:, None]
        return dj, dj.reshape(-1)

    # ---- sweep 2, per block: eff + Symphony cnt/cntop/step-min partials
    @pl.when(s == 2)
    def _sweep2():
        eff = eff_block()
        dj, djf = dj_block()
        sm4 = _onehot_take(smin_ref[...], dj).reshape(-1)
        pkts = eff * dt / mtu
        newly_done = active & (isent + eff * dt >= ichunk)
        act4 = per_hop(active, H)
        done4 = per_hop(newly_done, H)
        wire4 = per_hop(iwire, H)
        pkts4 = per_hop(pkts, H)
        cnt_s[...] = _segadd(cnt_s[...], djf,
                             jnp.where(act4, pkts4, 0.0), "onehot")
        cntop_s[...] = _segadd(cntop_s[...], djf,
                               jnp.where(act4 & (wire4 > sm4), pkts4, 0.0),
                               "onehot")
        cand_s[...] = _segmax(cand_s[...], djf,
                              jnp.where(done4, wire4 + 1, 0), "onehot")
        minact_s[...] = _segmin(minact_s[...], djf,
                                jnp.where(act4 & ~done4, wire4, _BIG),
                                "onehot")

    # ---- sweep 3, first block: finalize the Symphony step-min
    @pl.when((s == 3) & (b == 0))
    def _stepmin():
        cand = jnp.maximum(smin_ref[...], cand_s[...])
        stepmin_s[...] = jnp.where(minact_s[...] < _BIG,
                                   jnp.minimum(cand, minact_s[...]), cand)

    # ---- sweep 3, per block: psn-window partials + per-instance outputs
    @pl.when(s == 3)
    def _sweep3():
        eff = eff_block()
        _, djf = dj_block()
        pkts = eff * dt / mtu
        newly_done = active & (isent + eff * dt >= ichunk)
        send4 = per_hop(active & (eff > 1.0), H)
        done4 = per_hop(newly_done, H)
        wire4 = per_hop(iwire, H)
        psn4 = per_hop(ipsn + pkts, H)
        # state psn-window is always >= 0, so accumulating the >= 0
        # partials from 0 and max-ing with the state at the flush equals
        # the untiled segmax against the state directly
        psnwin_s[...] = _segmax(psnwin_s[...], djf,
                                jnp.where(send4 & ~done4 &
                                          (wire4 ==
                                           _onehot_take(stepmin_s[...], djf)),
                                          psn4, 0.0), "onehot")
        iroute_o[...] = iroute
        eff_o[...] = eff

    # ---- last grid step: flush the link/Symphony outputs
    @pl.when((s == 3) & (b == nb - 1))
    def _flush():
        off_p = offp_s[...] + bg
        off_q = (offhi_s[...] + bg) + offlo_s[...]
        if policy == "pq":
            offered = off_q
        else:
            offered = jnp.where(pq_on != 0, off_q, off_p)
        q = jnp.maximum(q_ref[...] + (offered - cap) * dt, 0.0)
        q = _zero_null_link(q, L, "onehot")
        offered_o[...] = offered
        q_o[...] = q
        pred_o[...] = jnp.clip((q - red_kmin) / (red_kmax - red_kmin),
                               0.0, 1.0) * red_pmax
        cnt = scnt_ref[...] + cnt_s[...]
        cntop = scntop_ref[...] + cntop_s[...]
        psnwin = jnp.maximum(spsn_ref[...], psnwin_s[...])
        sym_epoch = (tick % sym_win) == (sym_win - 1)
        have = cnt > n_sample
        exceed = cntop >= tau * cnt
        alpha_new = jnp.clip(
            salpha_ref[...] + jnp.where(exceed, 1.0, -1.0) * have,
            1.0, alpha_max)
        smin_o[...] = stepmin_s[...]
        spsn_o[...] = jnp.where(sym_epoch, 0.0, psnwin)
        salpha_o[...] = jnp.where(sym_epoch, alpha_new, salpha_ref[...])
        scnt_o[...] = jnp.where(sym_epoch, 0.0, cnt)
        scntop_o[...] = jnp.where(sym_epoch, 0.0, cntop)


def _edge_pad(x, n):
    """Pad the leading (instance) axis with ``n`` edge rows; lowers to
    slice + concatenate — no gather."""
    if not n:
        return x
    return jnp.pad(x, [(0, n)] + [(0, 0)] * (x.ndim - 1), mode="edge")


# --------------------------------------------------------- entry point
def netsim_tick(step_of, sent, rate, done_upto, q_prev,
                s_stepmin, s_psnwin, s_alpha, s_cnt, s_cntop,
                routes, path_table, n_paths, cap, link_dom, bg_base, bg_amp,
                inst_job, inst_flow, sps_i, phase_i, nph_i, off_i,
                chunk_sched, iscal, fscal, *,
                dt: float, mtu: float, per_step_ecmp: bool,
                policy: str = "proportional", segsum: str = "scatter",
                blk: int | None = None, tables=None,
                interpret: bool = True) -> TickOut:
    """One fused tick of the netsim hot path.

    Per-instance state is flat ``[FW]``; link state ``[L+1]``; Symphony
    state ``[DJ]``.  ``iscal = [tick, seed, bg_period_ticks,
    sym_win_ticks, pq_on]`` (i32) and ``fscal = [bg_duty, red_kmin,
    red_kmax, red_pmax, tau, n_sample, alpha_max]`` (f32) carry the
    traced scalars; ``dt``/``mtu``/``per_step_ecmp``/``policy``/``blk``
    are compile-time (from :class:`SimStructure`).

    ``blk`` < FW selects the tiled grid kernel (``segsum="onehot"``
    only): per-instance operands are BlockSpec-tiled into ``blk``-row
    blocks and the grid runs ``(TILED_SWEEPS, ceil(FW/blk))`` steps with
    cross-block reduction partials in persistent scratch.  The tiled
    kernel is gather-free and requires ``tables`` (a
    `params.PackedTables`, normally ``ctx.tables`` from
    `stages.make_ctx`): the packed per-instance route/chunk/ECMP tables
    are streamed block-by-block in place of the index-table operands,
    and the per-block valid-row counts ride in scalar prefetch.
    """
    if policy not in ("proportional", "pq"):
        raise ValueError(f"kernel share policy must be proportional|pq, "
                         f"got {policy!r}")
    if segsum not in SEGSUM_MODES:
        raise ValueError(f"segsum must be one of {SEGSUM_MODES}, "
                         f"got {segsum!r}")
    FW = step_of.shape[0]
    H = routes.shape[-1]
    L1 = cap.shape[0]
    DJ = s_stepmin.shape[0]
    if blk is not None:
        if segsum != "onehot":
            raise ValueError(
                f"blk={blk} tiling requires segsum='onehot' (Mosaic has no "
                f"vector scatter), got segsum={segsum!r}")
        if blk < 1:
            raise ValueError(f"blk must be >= 1, got {blk}")
    operands = (step_of, sent, rate, done_upto, q_prev,
                s_stepmin, s_psnwin, s_alpha, s_cnt, s_cntop,
                routes, path_table, n_paths, cap, link_dom, bg_base, bg_amp,
                inst_job, inst_flow, sps_i, phase_i, nph_i, off_i,
                chunk_sched, iscal, fscal)
    out_shape = [
        jax.ShapeDtypeStruct((FW, H), jnp.int32),   # iroute
        jax.ShapeDtypeStruct((FW,), jnp.float32),   # eff
        jax.ShapeDtypeStruct((L1,), jnp.float32),   # offered
        jax.ShapeDtypeStruct((L1,), jnp.float32),   # q
        jax.ShapeDtypeStruct((L1,), jnp.float32),   # p_red
        jax.ShapeDtypeStruct((DJ,), jnp.int32),     # s_stepmin
        jax.ShapeDtypeStruct((DJ,), jnp.float32),   # s_psnwin
        jax.ShapeDtypeStruct((DJ,), jnp.float32),   # s_alpha
        jax.ShapeDtypeStruct((DJ,), jnp.float32),   # s_cnt
        jax.ShapeDtypeStruct((DJ,), jnp.float32),   # s_cntop
    ]
    if blk is None or blk >= FW:
        kernel = functools.partial(
            _tick_kernel, H=H, SEG=int(chunk_sched.shape[-1]), dt=float(dt),
            mtu=float(mtu), per_step_ecmp=bool(per_step_ecmp), policy=policy,
            segsum=segsum)
        outs = pl.pallas_call(kernel, out_shape=out_shape,
                              interpret=interpret)(*operands)
        return TickOut(*outs)

    # ---------- tiled dispatch: grid over (sweep, instance block)
    if tables is None:
        raise ValueError(
            f"blk={blk} tiling requires packed route tables "
            "(params.PackedTables; use ctx.tables from stages.make_ctx): "
            "the gather-free tiled kernel streams per-instance tables "
            "instead of gathering from index-table operands")
    blk = int(blk)
    NB = -(-FW // blk)
    pad = NB * blk - FW
    J = int(chunk_sched.shape[0])

    def pad_i(x):                      # [FW, ...] -> [NB*blk, ...]
        return _edge_pad(x, pad)

    # done_upto expands [F] -> [FW] at trace time (repeat = broadcast +
    # reshape, gather-free) so it streams with the instance blocks.
    done_i = jnp.repeat(done_upto, FW // int(done_upto.shape[0]))
    operands = [pad_i(step_of), pad_i(sent), pad_i(rate), pad_i(done_i),
                q_prev, s_stepmin, s_psnwin, s_alpha, s_cnt, s_cntop,
                cap, bg_base, bg_amp,
                pad_i(inst_job), pad_i(inst_flow), pad_i(sps_i),
                pad_i(phase_i), pad_i(nph_i), pad_i(off_i),
                pad_i(tables.chunk)]
    if per_step_ecmp:
        operands += [pad_i(tables.cand), pad_i(tables.cand_dom),
                     pad_i(tables.n_paths)]
    else:
        operands += [pad_i(tables.routes), pad_i(tables.route_dom)]
    nroute = 3 if per_step_ecmp else 2
    operands += [iscal, fscal]

    # Per-block valid-row counts, built from Python ints: lane-INVARIANT,
    # which is what keeps vmap's pallas batching rule on the
    # grid-prepend path (batched scalar-prefetch operands would fall
    # back to a scan over lanes).
    nvalid = jnp.asarray([min(blk, FW - i * blk) for i in range(NB)],
                         jnp.int32)

    def blk_spec(a):                   # blocked per-instance operand
        return pl.BlockSpec((blk,) + a.shape[1:],
                            lambda s, b, nv: (b,) + (0,) * (a.ndim - 1))

    def full_spec(a):                  # whole-array operand
        return pl.BlockSpec(a.shape, lambda s, b, nv, nd=a.ndim: (0,) * nd)

    blocked = set(range(4)) | set(range(13, 20 + nroute))
    in_specs = [blk_spec(a) if i in blocked else full_spec(a)
                for i, a in enumerate(operands)]
    out_shape_t = list(out_shape)
    out_shape_t[0] = jax.ShapeDtypeStruct((NB * blk, H), jnp.int32)
    out_shape_t[1] = jax.ShapeDtypeStruct((NB * blk,), jnp.float32)
    out_specs = [
        pl.BlockSpec((blk, H), lambda s, b, nv: (b, 0)),    # iroute
        pl.BlockSpec((blk,), lambda s, b, nv: (b,)),        # eff
    ] + [full_spec(sh) for sh in out_shape_t[2:]]
    kernel = functools.partial(
        _tiled_tick_kernel, H=H, SEG=int(chunk_sched.shape[-1]),
        blk=blk, dt=float(dt), mtu=float(mtu),
        per_step_ecmp=bool(per_step_ecmp), policy=policy)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(TILED_SWEEPS, NB),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((J,), jnp.int32),        # jobmin
            pltpu.VMEM((L1,), jnp.float32),     # off_p partials
            pltpu.VMEM((L1,), jnp.float32),     # off_hi partials
            pltpu.VMEM((L1,), jnp.float32),     # off_lo partials
            pltpu.VMEM((L1,), jnp.float32),     # s_l scale
            pltpu.VMEM((L1,), jnp.float32),     # s_hi scale
            pltpu.VMEM((L1,), jnp.float32),     # s_lo scale
            pltpu.VMEM((DJ,), jnp.float32),     # cnt partials
            pltpu.VMEM((DJ,), jnp.float32),     # cntop partials
            pltpu.VMEM((DJ,), jnp.int32),       # cand partials
            pltpu.VMEM((DJ,), jnp.int32),       # min-active partials
            pltpu.VMEM((DJ,), jnp.int32),       # finalized step-min
            pltpu.VMEM((DJ,), jnp.float32),     # psn-window partials
        ],
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape_t,
        interpret=interpret,
    )(nvalid, *operands)
    outs = list(outs)
    outs[0] = outs[0][:FW]
    outs[1] = outs[1][:FW]
    return TickOut(*outs)
