"""Fused netsim tick hot path as a Pallas kernel.

The staged XLA engine (`core/netsim/stages.py`) runs each hot stage of a
tick — route gather, per-link scatter-add bandwidth sharing, queue/RED
integration, Symphony per-(domain, job) scatter — as a separate XLA op
with its own HBM round trip.  This kernel fuses them into one program:
the per-instance view, both share classes, the link queues, and the
Symphony state block updates are computed with everything resident
on-chip, and only the tick's true inputs/outputs touch HBM.

The stage functions stay the golden reference (`ref.py`): the kernel body
replays their op sequence exactly, so in interpret mode the fused tick is
**bit-for-bit** identical to the staged engine — the seed golden chain
(Table-1 finish-tick traces) holds under ``backend="pallas"``.

Share policies: ``proportional`` and ``pq`` are implemented in-kernel
(both classes are computed and the traced ``pq_on`` gate selects, exactly
like the XLA path's ``lax.cond``-under-vmap select); ``wfq``/``drr`` stay
on the XLA path behind `stages.resolve_backend`.

Segment reductions come in two flavors (``segsum=``):

* ``"scatter"`` — `.at[].add/max/min`, the reference op sequence;
  bitwise-equal to the staged engine (interpret mode).
* ``"onehot"``  — dense one-hot contractions (MXU matmul for the adds,
  masked row reductions for min/max).  Mosaic has no vector scatter, so
  this is the shape a compiled TPU lowering takes; adds reassociate, so
  it is allclose-not-bitwise vs the reference.

Compiled (non-interpret) execution is untested on this repo's CPU-only
CI — `ops.use_interpret` defaults to interpret mode on CPU hosts.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.netsim.stages import WIRE_SEG, per_hop

# stages.BIG as a Python int: the kernel body must not capture device
# constants (pallas requires all array operands to be explicit inputs).
_BIG = 2**30

SEGSUM_MODES = ("scatter", "onehot")


class TickOut(NamedTuple):
    """Fused-kernel outputs: everything the XLA-side stages still need."""
    iroute: jax.Array     # [FW, H]  selected per-instance routes
    eff: jax.Array        # [FW]     delivered bytes/s per instance
    offered: jax.Array    # [L+1]    offered load per link
    q: jax.Array          # [L+1]    integrated queues
    p_red: jax.Array      # [L+1]    RED marking profile
    s_stepmin: jax.Array  # [DJ]     Symphony state block (post-update)
    s_psnwin: jax.Array
    s_alpha: jax.Array
    s_cnt: jax.Array
    s_cntop: jax.Array


# ------------------------------------------------- segment reductions
def _rows(n: int, m: int) -> jax.Array:
    return jax.lax.broadcasted_iota(jnp.int32, (n, m), 0)


def _segadd(base, idx, vals, mode):
    """``base.at[idx].add(vals)``; dense mode uses a one-hot contraction
    (MXU-friendly, reassociates the adds — allclose, not bitwise)."""
    if mode == "scatter":
        return base.at[idx].add(vals)
    oh = _rows(base.shape[0], idx.shape[0]) == idx[None, :]
    if jnp.issubdtype(vals.dtype, jnp.floating):
        return base + jnp.dot(oh.astype(vals.dtype), vals,
                              preferred_element_type=vals.dtype)
    return base + jnp.where(oh, vals[None, :], 0).sum(axis=1)


def _segmax(base, idx, vals, mode):
    if mode == "scatter":
        return base.at[idx].max(vals)
    neutral = (jnp.finfo(vals.dtype).min
               if jnp.issubdtype(vals.dtype, jnp.floating)
               else jnp.iinfo(vals.dtype).min)
    oh = _rows(base.shape[0], idx.shape[0]) == idx[None, :]
    return jnp.maximum(base, jnp.where(oh, vals[None, :], neutral).max(axis=1))


def _segmin(base, idx, vals, mode):
    if mode == "scatter":
        return base.at[idx].min(vals)
    neutral = (jnp.finfo(vals.dtype).max
               if jnp.issubdtype(vals.dtype, jnp.floating)
               else jnp.iinfo(vals.dtype).max)
    oh = _rows(base.shape[0], idx.shape[0]) == idx[None, :]
    return jnp.minimum(base, jnp.where(oh, vals[None, :], neutral).min(axis=1))


# ------------------------------------------------------- kernel body
def _tick_kernel(step_ref, sent_ref, rate_ref, done_ref, q_ref,
                 smin_ref, spsn_ref, salpha_ref, scnt_ref, scntop_ref,
                 routes_ref, table_ref, npaths_ref, cap_ref, dom_ref,
                 bgb_ref, bga_ref,
                 job_ref, flow_ref, sps_ref, phase_ref, nph_ref, off_ref,
                 chunk_ref, iscal_ref, fscal_ref,
                 iroute_o, eff_o, offered_o, q_o, pred_o,
                 smin_o, spsn_o, salpha_o, scnt_o, scntop_o,
                 *, H, SEG, dt, mtu, per_step_ecmp, policy, segsum):
    istep = step_ref[...]
    isent = sent_ref[...]
    irate = rate_ref[...]
    inst_job = job_ref[...]
    inst_flow = flow_ref[...]
    sps = sps_ref[...]
    phase = phase_ref[...]
    nph = nph_ref[...]
    off = off_ref[...]
    cap = cap_ref[...]
    link_dom = dom_ref[...]
    chunk_sched = chunk_ref[...]
    tick, seed = iscal_ref[0], iscal_ref[1]
    bg_period, sym_win, pq_on = iscal_ref[2], iscal_ref[3], iscal_ref[4]
    bg_duty = fscal_ref[0]
    red_kmin, red_kmax, red_pmax = fscal_ref[1], fscal_ref[2], fscal_ref[3]
    tau, n_sample, alpha_max = fscal_ref[4], fscal_ref[5], fscal_ref[6]
    J = chunk_sched.shape[0]
    DJ = smin_ref.shape[0]
    L = cap.shape[0] - 1

    # ---- instance view (stages.instance_view, on-chip)
    iseg = (istep // sps) * nph + phase
    ichunk = chunk_sched[inst_job, jnp.clip(iseg, 0, SEG - 1)]
    iwire = iseg * WIRE_SEG + istep % sps + off
    occupied = istep >= 0
    retired = occupied & (istep < done_ref[...][inst_flow])
    complete = occupied & (isent >= ichunk)
    active = occupied & ~complete & ~retired
    ipsn = isent / mtu

    # ---- route selection (stages.select_routes)
    if per_step_ecmp:
        h = (inst_flow.astype(jnp.uint32) * jnp.uint32(2654435761)
             + jnp.maximum(istep, 0).astype(jnp.uint32) * jnp.uint32(40503)
             + (seed.astype(jnp.uint32) + 1) * jnp.uint32(2246822519))
        h = (h ^ (h >> 13)) * jnp.uint32(2654435761)
        h = h ^ (h >> 16)
        n_p = npaths_ref[...][inst_flow].astype(jnp.uint32)
        choice = (h % n_p).astype(jnp.int32)
        iroute = table_ref[...][inst_flow, choice]
    else:
        iroute = routes_ref[...][inst_flow]
    flat_links = iroute.reshape(-1)

    def lsum(vals):
        return _segadd(jnp.zeros(L + 1, jnp.float32), flat_links,
                       per_hop(vals, H), segsum)

    # ---- bandwidth sharing (stages.share_proportional / share_pq)
    bg_on = (tick % bg_period).astype(jnp.float32) < \
        bg_duty * bg_period.astype(jnp.float32)
    bg = bgb_ref[...] + jnp.where(bg_on, bga_ref[...], 0.0)
    w_rate = jnp.where(active, irate, 0.0)

    off_p = lsum(w_rate) + bg
    s_l = jnp.minimum(1.0, cap / jnp.maximum(off_p, 1.0))
    eff_p = w_rate * s_l[iroute].min(axis=1)

    job_min_wire = _segmin(jnp.full(J, _BIG, jnp.int32), inst_job,
                           jnp.where(active, iwire, _BIG), segsum)
    is_hi = active & (iwire <= job_min_wire[inst_job])
    hi_rate = jnp.where(is_hi, irate, 0.0)
    off_hi = lsum(hi_rate) + bg
    s_hi = jnp.minimum(1.0, cap / jnp.maximum(off_hi, 1.0))
    rem = jnp.maximum(cap - off_hi * s_hi, 0.0)
    lo_rate = jnp.where(active & ~is_hi, irate, 0.0)
    off_lo = lsum(lo_rate)
    s_lo = rem / jnp.maximum(off_lo, 1.0)
    share = jnp.where(is_hi[:, None], s_hi[iroute],
                      jnp.minimum(1.0, s_lo[iroute]))
    eff_q = w_rate * share.min(axis=1)
    off_q = off_hi + off_lo

    if policy == "pq":
        eff, offered = eff_q, off_q
    else:
        gate = pq_on != 0
        eff = jnp.where(gate, eff_q, eff_p)
        offered = jnp.where(gate, off_q, off_p)

    # ---- queues + RED (stages.stage_queues)
    q = jnp.maximum(q_ref[...] + (offered - cap) * dt, 0.0)
    q = q.at[L].set(0.0)
    p_red = jnp.clip((q - red_kmin) / (red_kmax - red_kmin),
                     0.0, 1.0) * red_pmax

    # ---- Symphony per-(domain, job) scatter (stages.stage_symphony)
    idom = link_dom[iroute]
    dj = idom * J + inst_job[:, None]
    djf = dj.reshape(-1)
    sm = smin_ref[...][dj]
    pkts = eff * dt / mtu
    newly_done = active & (isent + eff * dt >= ichunk)

    act4 = per_hop(active, H)
    send4 = per_hop(active & (eff > 1.0), H)
    done4 = per_hop(newly_done, H)
    wire4 = per_hop(iwire, H)
    psn4 = per_hop(ipsn + pkts, H)
    pkts4 = per_hop(pkts, H)
    sm4 = sm.reshape(-1)

    cnt = _segadd(scnt_ref[...], djf, jnp.where(act4, pkts4, 0.0), segsum)
    cntop = _segadd(scntop_ref[...], djf,
                    jnp.where(act4 & (wire4 > sm4), pkts4, 0.0), segsum)
    cand = _segmax(jnp.zeros(DJ, jnp.int32), djf,
                   jnp.where(done4, wire4 + 1, 0), segsum)
    cand = jnp.maximum(smin_ref[...], cand)
    min_act = _segmin(jnp.full(DJ, _BIG, jnp.int32), djf,
                      jnp.where(act4 & ~done4, wire4, _BIG), segsum)
    stepmin = jnp.where(min_act < _BIG, jnp.minimum(cand, min_act), cand)
    psnwin = _segmax(spsn_ref[...], djf,
                     jnp.where(send4 & ~done4 & (wire4 == stepmin[djf]),
                               psn4, 0.0), segsum)

    sym_epoch = (tick % sym_win) == (sym_win - 1)
    have = cnt > n_sample
    exceed = cntop >= tau * cnt
    alpha_new = jnp.clip(
        salpha_ref[...] + jnp.where(exceed, 1.0, -1.0) * have,
        1.0, alpha_max)

    iroute_o[...] = iroute
    eff_o[...] = eff
    offered_o[...] = offered
    q_o[...] = q
    pred_o[...] = p_red
    smin_o[...] = stepmin
    spsn_o[...] = jnp.where(sym_epoch, 0.0, psnwin)
    salpha_o[...] = jnp.where(sym_epoch, alpha_new, salpha_ref[...])
    scnt_o[...] = jnp.where(sym_epoch, 0.0, cnt)
    scntop_o[...] = jnp.where(sym_epoch, 0.0, cntop)


# --------------------------------------------------------- entry point
def netsim_tick(step_of, sent, rate, done_upto, q_prev,
                s_stepmin, s_psnwin, s_alpha, s_cnt, s_cntop,
                routes, path_table, n_paths, cap, link_dom, bg_base, bg_amp,
                inst_job, inst_flow, sps_i, phase_i, nph_i, off_i,
                chunk_sched, iscal, fscal, *,
                dt: float, mtu: float, per_step_ecmp: bool,
                policy: str = "proportional", segsum: str = "scatter",
                interpret: bool = True) -> TickOut:
    """One fused tick of the netsim hot path.

    Per-instance state is flat ``[FW]``; link state ``[L+1]``; Symphony
    state ``[DJ]``.  ``iscal = [tick, seed, bg_period_ticks,
    sym_win_ticks, pq_on]`` (i32) and ``fscal = [bg_duty, red_kmin,
    red_kmax, red_pmax, tau, n_sample, alpha_max]`` (f32) carry the
    traced scalars; ``dt``/``mtu``/``per_step_ecmp``/``policy`` are
    compile-time (from :class:`SimStructure`).
    """
    if policy not in ("proportional", "pq"):
        raise ValueError(f"kernel share policy must be proportional|pq, "
                         f"got {policy!r}")
    if segsum not in SEGSUM_MODES:
        raise ValueError(f"segsum must be one of {SEGSUM_MODES}, "
                         f"got {segsum!r}")
    FW = step_of.shape[0]
    H = routes.shape[-1]
    L1 = cap.shape[0]
    DJ = s_stepmin.shape[0]
    kernel = functools.partial(
        _tick_kernel, H=H, SEG=int(chunk_sched.shape[-1]), dt=float(dt),
        mtu=float(mtu), per_step_ecmp=bool(per_step_ecmp), policy=policy,
        segsum=segsum)
    outs = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((FW, H), jnp.int32),   # iroute
            jax.ShapeDtypeStruct((FW,), jnp.float32),   # eff
            jax.ShapeDtypeStruct((L1,), jnp.float32),   # offered
            jax.ShapeDtypeStruct((L1,), jnp.float32),   # q
            jax.ShapeDtypeStruct((L1,), jnp.float32),   # p_red
            jax.ShapeDtypeStruct((DJ,), jnp.int32),     # s_stepmin
            jax.ShapeDtypeStruct((DJ,), jnp.float32),   # s_psnwin
            jax.ShapeDtypeStruct((DJ,), jnp.float32),   # s_alpha
            jax.ShapeDtypeStruct((DJ,), jnp.float32),   # s_cnt
            jax.ShapeDtypeStruct((DJ,), jnp.float32),   # s_cntop
        ],
        interpret=interpret,
    )(step_of, sent, rate, done_upto, q_prev,
      s_stepmin, s_psnwin, s_alpha, s_cnt, s_cntop,
      routes, path_table, n_paths, cap, link_dom, bg_base, bg_amp,
      inst_job, inst_flow, sps_i, phase_i, nph_i, off_i,
      chunk_sched, iscal, fscal)
    return TickOut(*outs)
