"""Fused netsim tick hot path (Pallas).

Selected via ``SimParams(backend="pallas")``; the staged XLA engine in
`core/netsim/stages.py` stays the golden reference (`ref.py`).
"""
from .kernel import SEGSUM_MODES, TickOut, hot_tick, netsim_tick
from .ops import (PackedTables, engine_tick_fused, engine_window_fused,
                  fused_tick, pack_route_tables, plan_tiling, use_interpret)
from .ref import fused_outputs_ref, tick_ref, window_ref

__all__ = [
    "SEGSUM_MODES", "TickOut", "hot_tick", "netsim_tick",
    "engine_tick_fused", "engine_window_fused", "fused_tick",
    "PackedTables", "pack_route_tables", "plan_tiling", "use_interpret",
    "fused_outputs_ref", "tick_ref", "window_ref",
]
