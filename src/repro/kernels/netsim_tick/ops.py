"""Engine-facing entry points for the fused netsim tick kernel.

`stages.engine_tick` dispatches here when ``cfg.backend == "pallas"``:
:func:`engine_tick_fused` runs the hot stages (instance view, route
selection, bandwidth sharing, queue/RED, Symphony scatter) inside the
Pallas kernel and composes the remaining cheap stages (marking, progress,
rate control, segment barriers, metrics) around it on the XLA side —
bit-for-bit equal to `stages.engine_tick_xla` in interpret mode.

``REPRO_PALLAS_INTERPRET=0|1`` forces compiled/interpret execution;
unset, interpret mode is chosen automatically on CPU hosts (Pallas TPU
kernels cannot compile there; interpreted, the kernel traces into the
same XLA program as the staged engine, so this is a correctness path —
the perf win needs a real accelerator).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from ...core.netsim.params import (PackedTables, pack_route_tables,
                                   plan_tiling)
from ...core.netsim.stages import (EngineState, instance_view, stage_marking,
                                   stage_metrics, stage_progress,
                                   stage_rate_control, stage_segments,
                                   stage_starts, static_pq_on)
from .kernel import TickOut, netsim_tick

__all__ = ["use_interpret", "kernel_policy", "plan_tiling", "PackedTables",
           "pack_route_tables", "fused_tick", "compose_tick",
           "engine_tick_fused", "engine_window_fused"]


def use_interpret() -> bool:
    """Interpret-mode default: env override, else interpret on CPU."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() == "cpu"


def kernel_policy(cfg) -> str:
    """The in-kernel share policy for this config ("proportional"|"pq")."""
    if cfg.share_policy == "pq" or static_pq_on(cfg):
        return "pq"
    return "proportional"


def fused_tick(ctx, cfg, starts, state, tick, *,
               segsum: str | None = None,
               blk: int | None = None,
               interpret: bool | None = None) -> TickOut:
    """Marshal engine state into the kernel's flat operands and run it.

    ``segsum`` / ``blk`` default to the config's static fields (both
    overridable for direct kernel tests)."""
    st = ctx.st
    if segsum is None:
        segsum = getattr(cfg, "segsum", "scatter")
    if blk is None:
        blk = getattr(cfg, "blk", None)
    blk = plan_tiling(ctx.FW, blk, segsum, getattr(cfg, "tick_window", 1))
    i32 = lambda v: jnp.asarray(v, jnp.int32)
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    iscal = jnp.stack([i32(tick), i32(st.seed), i32(st.bg_period_ticks),
                       i32(cfg.sym_win_ticks), i32(cfg.pq_on)])
    fscal = jnp.stack([f32(st.bg_duty), f32(cfg.red_kmin), f32(cfg.red_kmax),
                       f32(cfg.red_pmax), f32(cfg.sym.tau),
                       f32(cfg.sym.n_sample), f32(cfg.sym.alpha_max)])
    return netsim_tick(
        starts.step_of.reshape(ctx.FW), starts.sent.reshape(ctx.FW),
        starts.rate.reshape(ctx.FW), state.done_upto, state.q,
        state.s_stepmin, state.s_psnwin, state.s_alpha,
        state.s_cnt, state.s_cntop,
        st.routes, st.path_table, st.n_paths, st.cap, st.link_dom,
        st.bg_base, st.bg_amp,
        ctx.inst_job, ctx.inst_flow, ctx.sps_i, ctx.phase_i, ctx.nph_i,
        ctx.off_i, ctx.wl.chunk_sched, iscal, fscal,
        dt=cfg.dt, mtu=cfg.mtu, per_step_ecmp=cfg.per_step_ecmp,
        policy=kernel_policy(cfg), segsum=segsum, blk=blk,
        tables=getattr(ctx, "tables", None),
        interpret=use_interpret() if interpret is None else interpret)


def compose_tick(ctx, cfg, state: EngineState, tick, starts, out: TickOut):
    """Compose the cheap stages around the fused hot-path outputs into the
    engine-tick contract ``(state', metric sample)``.  Shared between the
    per-tick path (XLA-side, around the pallas call) and the multi-tick
    window kernel (replayed inside the kernel body per tick)."""
    inst = instance_view(ctx, starts, state, cfg.mtu, cfg.per_step_ecmp,
                         iroute=out.iroute)
    lam, _pkts, _sm = stage_marking(ctx, cfg, state, inst, out.p_red,
                                    out.eff, starts.lam, tick)
    sent, done_upto, finish, _newly_done = stage_progress(
        ctx, cfg, state, inst, starts.step_of, out.eff, tick)
    rate, target, alpha_cc, stage, lam, key = stage_rate_control(
        ctx, cfg, starts, lam, state.key, tick)
    seg_idx, seg_ready, job_finish = stage_segments(ctx, state, done_upto,
                                                    tick)
    sample = stage_metrics(ctx, inst, done_upto, out.eff, out.q, out.s_alpha)
    new_state = EngineState(
        next_step=starts.next_step, done_upto=done_upto, finish=finish,
        step_of=starts.step_of, sent=sent, rate=rate, target=target,
        alpha_cc=alpha_cc, stage=stage, lam=lam, q=out.q,
        s_stepmin=out.s_stepmin, s_psnwin=out.s_psnwin, s_alpha=out.s_alpha,
        s_cnt=out.s_cnt, s_cntop=out.s_cntop,
        seg_idx=seg_idx, seg_ready=seg_ready, job_finish=job_finish,
        key=key,
    )
    return new_state, sample


def engine_tick_fused(ctx, cfg, state: EngineState, tick):
    """One tick with the hot stages fused; same contract as
    `stages.engine_tick_xla`: returns ``(state', metric sample)``."""
    starts = stage_starts(ctx, state, tick)
    out = fused_tick(ctx, cfg, starts, state, tick)
    return compose_tick(ctx, cfg, state, tick, starts, out)


def engine_window_fused(ctx, cfg, state: EngineState, base_tick, n: int):
    """Run ``n`` consecutive ticks inside ONE kernel invocation.

    The whole tick — start gating, the fused hot stages, marking,
    progress, rate control, segments, metrics — executes inside the
    Pallas kernel with the engine state carried through an in-kernel
    ``fori_loop``, so link/Symphony/instance state round-trips HBM once
    per window instead of once per tick.  Returns ``(state after n
    ticks, metric sample of the last tick)``.
    """
    from .window import netsim_window
    plan_tiling(ctx.FW, getattr(cfg, "blk", None),
                getattr(cfg, "segsum", "scatter"),
                getattr(cfg, "tick_window", 1))
    return netsim_window(ctx, cfg, state, base_tick, n,
                         policy=kernel_policy(cfg),
                         segsum=getattr(cfg, "segsum", "scatter"),
                         interpret=use_interpret())
