"""Oracle: exact Alg. 1 via core/symphony.py, with T_win flags."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.symphony import (Packet, SymphonyParams, SymphonyState,
                              init_state, process_packet, window_update)


def pipeline_ref(steps, psns, lasts, win_ends, uniforms,
                 params: SymphonyParams):
    """Sequential Alg. 1 + window updates. Returns (marks, step_min, psn_rec,
    alpha) trajectories — the post-packet state, matching the kernel."""
    def body(st, x):
        step, psn, last, wend, u = x
        st, mark = process_packet(st, Packet(step, psn, last > 0), params, u)
        st = jax.lax.cond(wend > 0, lambda s: window_update(s, params),
                          lambda s: s, st)
        return st, (mark, st.step_min, st.psn_rec, st.alpha)

    st = init_state()
    _, (marks, smin, prec, alpha) = jax.lax.scan(
        body, st, (steps.astype(jnp.int32), psns.astype(jnp.float32),
                   lasts.astype(jnp.int32), win_ends.astype(jnp.int32),
                   uniforms.astype(jnp.float32)))
    return marks.astype(jnp.int32), smin, prec, alpha
