"""Symphony switch data plane as a Pallas kernel (paper §4.7 analogue).

The Tofino2 prototype processes packets one-per-cycle through stateful ALUs
with only adds/compares and table lookups available — no division.  This
kernel reproduces that pipeline: a sequential walk over a packet batch,
carrying the Per-Job State Block (step_min, psn_rec, alpha, Cnt_total,
Cnt_op) in SMEM scratch, with two marking-probability paths:

  exact=True   float math, bit-identical to core/symphony.py (the oracle)
  exact=False  ASIC path: P and the coin toss compared in log2 domain using
               a 16-entry mantissa lookup table (the paper's "logarithms and
               hardware lookup tables" trick) — state updates stay exact,
               only the stochastic mark decision is approximated.

Inputs per packet: step, psn, LAST bit, window-end flag (T_win boundary),
uniform sample.  Outputs: mark decision + the post-packet (step_min, psn_rec,
alpha) trajectory for exact oracle comparison.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# 16-entry mantissa log2 LUT: log2(1 + i/16), the kind of table a switch ALU
# indexes with the mantissa's top 4 bits.
_LOG2_LUT = np.log2(1.0 + np.arange(16) / 16.0).astype(np.float32)


def _lut_log2(x: jax.Array, lut: jax.Array) -> jax.Array:
    """Piecewise-constant log2 via exponent extraction + 16-entry LUT."""
    e = jnp.floor(jnp.log2(jnp.maximum(x, 1e-30)))      # exponent (ASIC: CLZ)
    m = x / jnp.exp2(e)                                  # mantissa in [1, 2)
    idx = jnp.clip(((m - 1.0) * 16).astype(jnp.int32), 0, 15)
    return e + lut[idx]


def _pipeline_kernel(lut_ref, steps_ref, psns_ref, lasts_ref, wins_ref, u_ref,
                     marks_ref, smin_ref, prec_ref, alpha_ref,
                     st_ref, *, blk, k, tau, n_warmup, n_sample, alpha_max,
                     exact):
    b = pl.program_id(0)
    lut = lut_ref[...]

    @pl.when(b == 0)
    def _():
        st_ref[...] = jnp.zeros_like(st_ref)
        st_ref[2] = jnp.float32(1.0)   # alpha(0) = 1

    def body(i, st):
        step_min, psn_rec, alpha, cnt, cnt_op = st
        step = steps_ref[i].astype(jnp.float32)
        psn = psns_ref[i].astype(jnp.float32)
        is_last = lasts_ref[i] > 0
        win_end = wins_ref[i] > 0
        u = u_ref[i]

        # UpdateTrafficStats (pre-update state)
        is_op = step > step_min
        cnt = cnt + 1.0
        cnt_op = cnt_op + jnp.where(is_op, 1.0, 0.0)

        # marking decision against the found state (Alg. 1 l.11-17)
        outpacing = is_op & (psn_rec > n_warmup)
        if exact:
            p = jnp.minimum(1.0, k * alpha * psn / jnp.maximum(psn_rec, 1.0))
            mark = outpacing & (u < p)
        else:
            # log2-domain compare: log2(u) < log2(k) + log2(alpha) +
            # log2(psn) - log2(psn_rec); min(1, .) becomes sign check.
            lp = (_lut_log2(jnp.float32(k), lut) + _lut_log2(alpha, lut) +
                  _lut_log2(jnp.maximum(psn, 1.0), lut) -
                  _lut_log2(jnp.maximum(psn_rec, 1.0), lut))
            mark = outpacing & (_lut_log2(jnp.maximum(u, 1e-9), lut) < lp)

        # progress tracking (Alg. 1 l.3-10)
        lt = step < step_min
        eq = step == step_min
        step_min = jnp.where(is_last, step + 1.0,
                             jnp.where(lt, step, step_min))
        psn_rec = jnp.where(is_last, 0.0,
                            jnp.where(lt, psn,
                                      jnp.where(eq, jnp.maximum(psn_rec, psn),
                                                psn_rec)))

        # T_win boundary: Eq. 5 integer test + windowed psn reset
        have = cnt > n_sample
        exceed = cnt_op >= tau * cnt
        alpha_w = jnp.clip(alpha + jnp.where(exceed, 1.0, -1.0) * have,
                           1.0, alpha_max)
        alpha = jnp.where(win_end, alpha_w, alpha)
        cnt = jnp.where(win_end, 0.0, cnt)
        cnt_op = jnp.where(win_end, 0.0, cnt_op)
        psn_rec = jnp.where(win_end, 0.0, psn_rec)

        marks_ref[i] = mark.astype(jnp.int32)
        smin_ref[i] = step_min.astype(jnp.int32)
        prec_ref[i] = psn_rec
        alpha_ref[i] = alpha
        return (step_min, psn_rec, alpha, cnt, cnt_op)

    st = (st_ref[0], st_ref[1], st_ref[2], st_ref[3], st_ref[4])
    st = jax.lax.fori_loop(0, blk, body, st)
    st_ref[...] = jnp.stack(st)


def switch_pipeline(steps, psns, lasts, win_ends, uniforms, *,
                    k=0.01, tau=0.25, n_warmup=16, n_sample=32,
                    alpha_max=64.0, exact=True, blk=256, interpret=True):
    """Process a packet batch through Alg. 1.  All inputs [P].
    Returns (marks i32, step_min i32, psn_rec f32, alpha f32) per packet."""
    P = steps.shape[0]
    pad = (-P) % blk
    if pad:
        z = lambda a, v=0: jnp.pad(a, (0, pad), constant_values=v)
        steps, psns = z(steps), z(psns)
        lasts, win_ends = z(lasts), z(win_ends)
        uniforms = z(uniforms, 1.0)
    Pp = steps.shape[0]
    grid = (Pp // blk,)
    kernel = functools.partial(
        _pipeline_kernel, blk=blk, k=float(k), tau=float(tau),
        n_warmup=float(n_warmup), n_sample=float(n_sample),
        alpha_max=float(alpha_max), exact=exact)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((16,), lambda b: (0,))] +
                 [pl.BlockSpec((blk,), lambda b: (b,))] * 5,
        out_specs=[pl.BlockSpec((blk,), lambda b: (b,))] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((Pp,), jnp.int32),
            jax.ShapeDtypeStruct((Pp,), jnp.int32),
            jax.ShapeDtypeStruct((Pp,), jnp.float32),
            jax.ShapeDtypeStruct((Pp,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((5,), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(_LOG2_LUT), steps.astype(jnp.int32),
      psns.astype(jnp.float32), lasts.astype(jnp.int32),
      win_ends.astype(jnp.int32), uniforms.astype(jnp.float32))
    marks, smin, prec, alpha = outs
    return marks[:P], smin[:P], prec[:P], alpha[:P]
