"""jit'd wrapper for the switch-pipeline kernel."""
from __future__ import annotations

import os

from .kernel import switch_pipeline  # noqa: F401

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"
