"""jit'd wrapper: flash attention with custom VJP (Pallas fwd + bwd kernels).

Public entry `flash_attention(q, k, v, q_pos, k_pos, window=0)` matches the
model-side calling convention ([B, S, H, D] layout, contiguous positions).
`interpret` defaults to True because this container is CPU-only; on TPU set
REPRO_PALLAS_INTERPRET=0.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import flash_bwd, flash_fwd

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash(q, k, v, window, causal):
    o, _ = flash_fwd(q, k, v, scale=1.0 / np.sqrt(q.shape[-1]),
                     window=window, causal=causal, interpret=INTERPRET)
    return o


def _flash_fwd_rule(q, k, v, window, causal):
    o, lse = flash_fwd(q, k, v, scale=1.0 / np.sqrt(q.shape[-1]),
                       window=window, causal=causal, interpret=INTERPRET)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(window, causal, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_bwd(q, k, v, o, lse, do,
                           scale=1.0 / np.sqrt(q.shape[-1]),
                           window=window, causal=causal, interpret=INTERPRET)
    group = q.shape[0] // k.shape[0]
    if group > 1:
        # dk/dv come back per-q-head; reduce over each GQA group
        dk = dk.reshape(k.shape[0], group, *k.shape[1:]).sum(1)
        dv = dv.reshape(v.shape[0], group, *v.shape[1:]).sum(1)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, q_pos=None, k_pos=None, *, window: int = 0,
                    causal: bool = True) -> jax.Array:
    """q: [B, S, Hq, D]; k/v: [B, S, Hkv, D] -> [B, S, Hq, D].

    Assumes contiguous positions (q_pos/k_pos accepted for API parity with
    the reference; the kernel derives positions from block indices).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    of = _flash(qf, kf, vf, window, causal)
    return of.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
