"""Pure-jnp oracle for the flash attention kernel (fp32 softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q, k, v, *, window: int = 0, causal: bool = True):
    """q: [BH, S, D]; k/v: [BHkv, S, D] (GQA: BH = BHkv * group).
    Returns (o [BH,S,D], lse [BH,S])."""
    BH, S, D = q.shape
    group = BH // k.shape[0]
    kr = jnp.repeat(k, group, axis=0)
    vr = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / np.sqrt(D)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1)
    o = jnp.einsum("bqk,bkd->bqd", p / jnp.maximum(l[..., None], 1e-30),
                   vr.astype(jnp.float32))
    lse = (m[..., 0] + jnp.log(jnp.maximum(l, 1e-30)))
    return o.astype(q.dtype), lse
