"""Flash attention forward + backward Pallas TPU kernels.

Tiling: grid (batch*heads, q_blocks, kv_blocks); the kv axis is the minormost
grid dimension, so the online-softmax accumulators live in VMEM scratch
across kv iterations (TPU grid order is sequential).  Blocks are 128-aligned
for the MXU; masking covers causal + sliding-window + GQA head-group
mapping (kv rows indexed as (b*Hkv + h // group)).

Backward: two kernels —
  * dq:    grid (BH, iq, jk), accumulate dq over jk in VMEM scratch
  * dk/dv: grid (BH, jk, iq), accumulate dk, dv over iq in VMEM scratch
using the saved LSE and delta = rowsum(dO * O), the standard FlashAttention-2
recomputation scheme.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _mask(iq, jk, bq, bk, window, causal, neg=NEG_INF):
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, neg)


# ----------------------------------------------------------------- forward


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, scale, window, causal, bq, bk, nk):
    jk = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(jk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip fully-masked blocks (causal upper triangle)
    run = True
    if causal:
        run = (jk * bk) <= (iq * bq + bq - 1)

    @pl.when(run if causal else jk >= 0)
    def _():
        q = q_ref[0].astype(jnp.float32)                 # [bq, d]
        k = k_ref[0].astype(jnp.float32)                 # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + _mask(iq, jk, bq, bk, window, causal)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l)).astype(lse_ref.dtype)


def flash_fwd(q, k, v, *, scale, window=0, causal=True, bq=DEFAULT_BQ,
              bk=DEFAULT_BK, interpret=True):
    """q: [BH, S, D]; k/v: [BHkv, S, D] with BH = BHkv * group.
    Returns (o [BH,S,D], lse [BH,S])."""
    BH, S, D = q.shape
    BHkv = k.shape[0]
    group = BH // BHkv
    nq, nk = S // bq, S // bk
    grid = (BH, nq, nk)
    kernel = functools.partial(_fwd_kernel, scale=scale, window=window,
                               causal=causal, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, D), q.dtype),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ----------------------------------------------------------------- backward


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, window, causal, bq, bk, nk):
    jk = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(jk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        run = (jk * bk) <= (iq * bq + bq - 1)

    @pl.when(run if causal else jk >= 0)
    def _():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + _mask(iq, jk, bq, bk, window, causal)
        p = jnp.exp(s - lse_ref[0][:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jk == nk - 1)
    def _():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, scale, window, causal, bq, bk, nq, group):
    iq = pl.program_id(2)
    jk = pl.program_id(1)
    bh = pl.program_id(0)

    @pl.when(iq == 0)
    def _():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    run = True
    if causal:
        run = (jk * bk) <= (iq * bq + bq - 1)

    @pl.when(run if causal else iq >= 0)
    def _():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = s + _mask(iq, jk, bq, bk, window, causal)
        p = jnp.exp(s - lse_ref[0][:, None])                 # [bq, bk]
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # [bk, d]
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale        # [bq, bk]
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # [bk, d]

    @pl.when(iq == nq - 1)
    def _():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_bwd(q, k, v, o, lse, do, *, scale, window=0, causal=True,
              bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=True):
    """Returns (dq [BH,S,D], dk, dv [BH,S,D] per-q-head; caller reduces
    over GQA groups)."""
    BH, S, D = q.shape
    BHkv = k.shape[0]
    group = BH // BHkv
    nq, nk = S // bq, S // bk
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, window=window,
                          causal=causal, bq=bq, bk=bk, nk=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, window=window,
                          causal=causal, bq=bq, bk=bk, nq=nq, group=group),
        grid=(BH, nk, nq),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b // group, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b // group, j, 0)),
            pl.BlockSpec((1, bq, D), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, D), jnp.float32),
            jax.ShapeDtypeStruct((BH, S, D), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),
            pltpu.VMEM((bk, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
