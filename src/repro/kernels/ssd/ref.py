"""Oracle for the SSD kernel: re-export the model-side chunked reference."""
from ...models.ssm import segsum_exp, ssd_reference  # noqa: F401
