"""jit'd wrapper for the SSD kernel, model-side calling convention."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .kernel import ssd_chunked

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def ssd(x, a, Bm, Cm, *, chunk: int = 128):
    """x: [B, S, H, P] dt-scaled inputs; a: [B, S, H] log decay;
    Bm/Cm: [B, S, N].  Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, Sp, P)
    af = a.transpose(0, 2, 1).reshape(B * H, Sp)
    y, fs = ssd_chunked(xf, af, Bm, Cm, chunk=chunk, n_heads=H,
                        interpret=INTERPRET)
    y = y.reshape(B, H, Sp, P).transpose(0, 2, 1, 3)[:, :S]
    final = fs.reshape(B, H, N, P).transpose(0, 1, 3, 2)   # [B,H,P,N]
    return y, final
