"""Mamba-2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

Grid (B*H, n_chunks); the chunk axis is sequential, carrying the running
inter-chunk state [N, P] in VMEM scratch.  Per chunk (Q = chunk length):

  intra:  y_diag = (C B^T * L) x        (quadratic within the chunk, MXU)
  carry:  y_off  = (C * exp(cum)) state
  update: state  = state * exp(cum[-1]) + (B * decay_to_end)^T x

B/C are shared across the H heads of a batch row (single SSD group), indexed
with bh // H in the BlockSpec index maps.  All accumulation is fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, fs_ref, state_ref,
                *, nc, Q):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)            # [Q, P]
    a = a_ref[0].astype(jnp.float32)            # [Q]
    Bm = b_ref[0].astype(jnp.float32)           # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)           # [Q, N]

    cum = jnp.cumsum(a)                         # [Q]
    seg = cum[:, None] - cum[None, :]           # [Q, Q] sum over (j, i]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, Q]
    y = jax.lax.dot_general(G * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]

    state = state_ref[...]                      # [N, P]
    y += jax.lax.dot_general(Cm * jnp.exp(cum)[:, None], state,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum)       # [Q]
    state_new = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        Bm * decay_to_end[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # [N, P]
    state_ref[...] = state_new

    @pl.when(c_idx == nc - 1)
    def _():
        fs_ref[0] = state_new.astype(fs_ref.dtype)


def ssd_chunked(x, a, Bm, Cm, *, chunk: int, n_heads: int, interpret=True):
    """x: [BH, S, P]; a: [BH, S]; Bm/Cm: [B, S, N] (shared across heads).
    Returns (y [BH,S,P], final_state [BH,N,P])."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q
    H = n_heads
    kernel = functools.partial(_ssd_kernel, nc=nc, Q=Q)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q), lambda b, c: (b, c)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b // H, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b // H, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, a, Bm, Cm)
