"""Paper Fig. 8: robustness sweeps.

(a) load-imbalance ratio 1.1x-1.7x: Symphony's relative gain grows with
    imbalance;
(b) throttling gain k: broad sweet spot 1e-3..1e-2, degradation at extremes;
(c) chunk size: gains grow with chunk >= 512 kB, vanish at 128 kB.

All sweeps dispatch through ``simulate_grid``: every (sym on/off, knob
value) pair of a panel shares one compiled program, vmapped over knob
points x seeds.  Panel (b) — the pure-knob sweep — is a single grid call;
(a) varies the background-load arrays and (c) the horizon, so those loop
per point but still reuse one engine compilation per structure.
"""
import numpy as np

from repro.core.netsim import core_trace_count, metrics, resolve_grid_mesh
from repro.core.symphony import SymphonyParams

from .common import (QUICK, build_scenario, cached, default_params,
                     run_scenario_grid, run_grid, seeds_for, sweep_axes_for,
                     table1_topo, table1_workload)

# single source of truth for the sweep parameters and the cache key
CONFIG = dict(hosts=32 if QUICK else 64,
              passes=3 if QUICK else 4,
              ratios=(1.1, 1.4, 1.7) if QUICK else (1.1, 1.3, 1.5, 1.7),
              chunks=(128e3, 512e3, 8e6) if QUICK
                     else (128e3, 512e3, 2e6, 8e6),
              n_seeds=len(seeds_for(8, 2)))


def _median_cct(res, wl, cfg):
    return np.nanmedian(metrics.cct_seconds(res, wl, cfg)[..., 0], axis=-1)


def _gain_pair(topo, wl, cfg_b, cfg_s, seeds, routing="ecmp", **bg):
    """Relative JCT gain of cfg_s over cfg_b, both run in one 2-point grid."""
    res = run_grid(topo, wl, [cfg_b, cfg_s], seeds, routing, **bg)
    jb, js = _median_cct(res, wl, cfg_b)
    if not (np.isfinite(jb) and np.isfinite(js)):
        return None
    return round(float(1 - js / jb), 4)


def run():
    out = {}
    seeds = list(range(CONFIG["n_seeds"]))
    hosts = CONFIG["hosts"]
    topo = table1_topo(hosts)
    ring = 8 if hosts == 32 else 32
    passes = CONFIG["passes"]
    wl = table1_workload(n_hosts=hosts, ring=ring, passes=passes,
                         barrier=False)
    horizon = int((0.12 * passes + 0.6) / 10e-6)

    # (a) load imbalance: background share on one uplink, balanced routing.
    # bg arrays live in Static (not knobs), so each ratio is its own grid
    # call — but shapes repeat, so the engine compiles once for the panel.
    for ratio in CONFIG["ratios"]:
        bg = np.zeros(topo.n_links)
        up = topo.uplink(0, 0)
        bg[up] = (ratio - 1.0) * topo.link_cap[up]
        g = _gain_pair(topo, wl, default_params(horizon),
                       default_params(horizon, sym=True), seeds,
                       routing="balanced", bg_base=bg)
        out[f"imbalance_{ratio}"] = {"jct_improvement": g}

    # (b) k sweep on the 2-D ring pattern: baseline + every k value in ONE
    # grid call (k and sym_on are RuntimeKnobs), using the registry's
    # declared sweep axis.
    d0 = 8 if hosts == 32 else 16
    _, wl2, _, _ = build_scenario("table1_2d", n_hosts=hosts, d0=d0,
                                  passes=passes)
    horizon2 = int((0.25 * passes + 0.6) / 10e-6)
    ks = list(sweep_axes_for("table1_2d")["k"])
    base2 = default_params(horizon2)
    cfgs = [base2] + [base2._replace(sym_on=True, sym=SymphonyParams(k=k))
                      for k in ks]
    res = run_grid(topo, wl2, cfgs, seeds, "ecmp")
    med = _median_cct(res, wl2, base2)          # [1 + len(ks)]
    for i, k in enumerate(ks):
        g = (round(float(1 - med[1 + i] / med[0]), 4)
             if np.isfinite(med[0]) and np.isfinite(med[1 + i]) else None)
        out[f"k_{k:g}"] = {"jct_improvement": g}

    # (c) chunk-size sweep: the horizon (n_ticks, static structure) tracks
    # the chunk, so each chunk compiles once; sym on/off rides in one grid.
    for chunk in CONFIG["chunks"]:
        wl3 = table1_workload(n_hosts=hosts, ring=ring,
                              passes=passes, chunk=chunk, barrier=False)
        hz = int((0.12 * passes * chunk / 8e6 + 0.4) / 10e-6)
        g = _gain_pair(topo, wl3, default_params(hz),
                       default_params(hz, sym=True), seeds)
        out[f"chunk_{int(chunk/1e3)}kB"] = {"cct_improvement": g}
    return out


def bench():
    return cached("fig8_sweeps", run,
                  config=CONFIG | {"k_axis": sweep_axes_for("table1_2d")["k"]})


def sharded_smoke(n_hosts: int = 128, seeds=(0,)) -> dict:
    """Fig-8-at-scale smoke for CI: the registry-driven multipod sweep at
    ``n_hosts`` on the 3-tier FatTree, lanes sharded over all local
    devices (force a CPU mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

    Returns the compile count (must be 1), the device count actually
    used, and the per-point median CCTs, so the CI gate exercises the
    sharded dispatch end-to-end on every PR."""
    mesh = resolve_grid_mesh(devices="auto")
    c0 = core_trace_count()
    # horizon 6x lockstep ideal: ECMP collisions on the oversubscribed
    # core tier stretch the tail to ~5.2x ideal at 128 hosts
    built, cfgs, res = run_scenario_grid(
        "fat_tree_multipod", seeds=list(seeds), devices="auto",
        n_hosts=n_hosts, ring=8, chunk=512e3, horizon_mult=6.0)
    compiles = core_trace_count() - c0
    med = _median_cct(res, built.wl, built.cfg)
    return {
        "n_hosts": n_hosts,
        "grid_points": len(cfgs),
        "device_count": 1 if mesh is None else int(mesh.devices.size),
        "grid_compiles": compiles,
        "cct_median_s": [round(float(m), 4) if np.isfinite(m) else None
                         for m in med],
        "n_unfinished": int(np.isnan(np.asarray(med)).sum()),
    }
