"""Paper Fig. 8: robustness sweeps.

(a) load-imbalance ratio 1.1x-1.7x: Symphony's relative gain grows with
    imbalance;
(b) throttling gain k: broad sweet spot 1e-3..1e-2, degradation at extremes;
(c) chunk size: gains grow with chunk >= 512 kB, vanish at 128 kB.
"""
import numpy as np

from repro.core.netsim import metrics
from repro.core.symphony import SymphonyParams

from .common import (QUICK, build_scenario, cached, default_params,
                     run_seeds, seeds_for, table1_topo, table1_workload)


def _gain(topo, wl, cfg_b, cfg_s, seeds, routing="ecmp", **bg):
    rb = run_seeds(topo, wl, cfg_b, routing, seeds, **bg)
    rs = run_seeds(topo, wl, cfg_s, routing, seeds, **bg)
    jb = np.nanmedian(metrics.cct_seconds(rb, wl, cfg_b)[:, 0])
    js = np.nanmedian(metrics.cct_seconds(rs, wl, cfg_s)[:, 0])
    if not (np.isfinite(jb) and np.isfinite(js)):
        return None
    return round(float(1 - js / jb), 4)


def run():
    out = {}
    seeds = seeds_for(8, 2)
    hosts = 32 if QUICK else 64
    topo = table1_topo(hosts)
    ring = 8 if hosts == 32 else 32
    passes = 3 if QUICK else 4
    wl = table1_workload(n_hosts=hosts, ring=ring, passes=passes,
                         barrier=False)
    horizon = int((0.12 * passes + 0.6) / 10e-6)

    # (a) load imbalance: background share on one uplink, balanced routing
    for ratio in ([1.1, 1.4, 1.7] if QUICK else [1.1, 1.3, 1.5, 1.7]):
        bg = np.zeros(topo.n_links)
        up = topo.uplink(0, 0)
        bg[up] = (ratio - 1.0) * topo.link_cap[up]
        g = _gain(topo, wl, default_params(horizon),
                  default_params(horizon, sym=True), seeds,
                  routing="balanced", bg_base=bg)
        out[f"imbalance_{ratio}"] = {"jct_improvement": g}

    # (b) k sweep on 2-D ring pattern (registry scenario)
    d0 = 8 if hosts == 32 else 16
    _, wl2, _, _ = build_scenario("table1_2d", n_hosts=hosts, d0=d0,
                                  passes=passes)
    horizon2 = int((0.25 * passes + 0.6) / 10e-6)
    for k in ([1e-4, 1e-3, 1e-2, 1e-1] if not QUICK else [1e-3, 1e-2, 1e-1]):
        cfg_s = default_params(horizon2, sym=True)._replace(
            sym=SymphonyParams(k=k))
        g = _gain(topo, wl2, default_params(horizon2), cfg_s, seeds)
        out[f"k_{k:g}"] = {"jct_improvement": g}

    # (c) chunk-size sweep
    for chunk in ([128e3, 512e3, 8e6] if QUICK
                  else [128e3, 512e3, 2e6, 8e6]):
        wl3 = table1_workload(n_hosts=hosts, ring=ring,
                              passes=passes, chunk=chunk, barrier=False)
        hz = int((0.12 * passes * chunk / 8e6 + 0.4) / 10e-6)
        g = _gain(topo, wl3, default_params(hz),
                  default_params(hz, sym=True), seeds)
        out[f"chunk_{int(chunk/1e3)}kB"] = {"cct_improvement": g}
    return out


def bench():
    return cached("fig8_sweeps", run)
