"""Paper Table 2: end-to-end data-parallel training, gradient-sync phase.

Workloads mirror the Astra-Sim suite: VGG16 (large uneven gradient buckets,
comm-bound), ResNet50 (smaller buckets), Transformer (hybrid DP+MP,
compute-bound).  Gradient bucket schedules are derived from the real layer
shapes; the compute gap models the per-iteration forward+backward time.

Paper targets (JCT reduction): VGG-128 50.2%, VGG-512 54.4%, ResNet-128
24.3%, ResNet-512 20.8%, Transformer ~0.07%.
"""
import numpy as np

from repro.core.netsim import metrics

from .common import (QUICK, cached, params_for_seconds, run_grid,
                     seeds_for, table1_topo, table1_workload)

# per-iteration all-reduce bucket sizes (bytes/node), fp16 grads, bucketed
# at ~25MB like DDP: VGG16 ~138M params dominated by fc1 (102M); ResNet50
# ~25.6M params.
VGG_BUCKETS = [52e6, 52e6, 52e6, 52e6, 25e6, 20e6, 12e6, 8e6, 4e6]
RESNET_BUCKETS = [13e6, 13e6, 13e6, 9e6, 3e6]
TRANSFORMER_BUCKETS = [16e6, 16e6, 16e6, 16e6]


def _jobs(n_hosts, buckets, gap, iters, ring):
    """One iteration = len(buckets) collectives (per-bucket chunk schedule)
    + a compute gap before each iteration."""
    sched = list(np.repeat(buckets, 1)) * iters
    # chunk per step = bucket / ring members
    sched = [b / ring for b in sched]
    wl = table1_workload(n_hosts=n_hosts, ring=ring, passes=len(sched),
                         barrier=True, compute_gap=gap,
                         chunk_schedule=sched)
    # gap applies before every pass; we want it per ITERATION only: emulate
    # by folding the gap into the first bucket of each iteration is complex;
    # instead scale the gap down by buckets/iter.
    return wl


def run():
    iters = 2 if QUICK else 4
    seeds = seeds_for(8, 2)
    out = {}
    cases = [
        ("vgg_128", 128, VGG_BUCKETS, 0.030),
        ("resnet_128", 128, RESNET_BUCKETS, 0.060),
        ("transformer_128", 128, TRANSFORMER_BUCKETS, 1.5),
    ]
    if not QUICK:
        # 512-node case: VGG only (the paper's headline cell); resnet_512
        # omitted from the default suite for wall-clock (same machinery).
        cases += [("vgg_512", 512, VGG_BUCKETS, 0.030)]
    for name, hosts, buckets, gap in cases:
        ring = 8 if hosts == 32 else 32
        topo = table1_topo(hosts)
        gap_per_pass = gap / len(buckets)
        wl = _jobs(hosts, buckets, gap_per_pass, iters, ring)
        ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)
        cfg_b = params_for_seconds(min(ideal * 3.0 + 0.3, 6.0), coarse=True)
        cfg_s = params_for_seconds(min(ideal * 3.0 + 0.3, 6.0), sym=True,
                                   coarse=True)
        # baseline + symphony differ only in RuntimeKnobs, so both run as
        # ONE 2-point grid (one compile; lanes shard across devices when
        # BENCH_DEVICES / an explicit mesh asks for it)
        res = run_grid(topo, wl, [cfg_b, cfg_s], seeds, "ecmp")
        cct = metrics.cct_seconds(res, wl, cfg_b)[..., 0]   # [2, S]
        jb, js = cct[0], cct[1]
        out[name] = {
            "baseline_jct_s": float(np.nanmean(jb)),
            "symphony_jct_s": float(np.nanmean(js)),
            "improvement": round(1 - np.nanmean(js) / np.nanmean(jb), 4)
            if np.isfinite(np.nanmean(jb)) else None,
            "ideal_s": ideal,
        }
    return out


def bench():
    return cached("table2_e2e", run)
