"""The 512-host multi-pod sweep artifact — ``BENCH_grid512.json``.

Runs the registry-driven Table-2/Fig-8-style knob sweep (sym x tau x k x
T_win, x seeds) on the 3-tier multi-pod FatTree at 128/256/512 hosts
through the sharded grid executor (``simulate_grid(devices="auto")``),
and measures lane-scaling efficiency: lanes/sec per device and the
1 -> N-device grid speedup on the same program.

The committed artifact tracks two things across PRs:

* the sweep itself (best Symphony operating point + improvement per host
  count) — the paper's dense evaluation grid, at Swing/DS-Sync scale;
* the scaling numbers — whether the flattened ``K*S`` lane axis actually
  spreads across devices.  On a single-core CI/dev host the forced
  8-device CPU mesh buys nothing (all shards serialize on one core, so
  ``speedup_1_to_n`` honestly reports ~1.0 or below, exactly like the
  committed ``grid_speedup_vs_per_point = 0.87``); on multi-core or
  accelerator hosts the same artifact records real scaling.

Regenerate with::

    PYTHONPATH=src python -m benchmarks.grid512            # quick mode
    BENCH_QUICK=0 PYTHONPATH=src python -m benchmarks.grid512   # full

Run as a script it forces ``--xla_force_host_platform_device_count=8``
on CPU hosts (set ``XLA_FLAGS`` yourself to override) so the sharded
path is exercised even without accelerators.
"""
import os

if "XLA_FLAGS" not in os.environ:  # must precede the first jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import platform
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.netsim import core_trace_count, metrics, resolve_grid_mesh

from .common import (QUICK, build_scenario, knob_combos, knob_grid, run_grid,
                     sweep_axes_for)

BENCH_FILE = Path(__file__).resolve().parents[1] / "BENCH_grid512.json"
BENCH_SCHEMA = 1

SCENARIO = "fat_tree_multipod"

# single source of truth for the artifact parameters.  Quick mode is what
# a 1-core host can regenerate in ~half an hour: small per-step chunks,
# ring-of-8 stripes, 2.5x horizons.  Full mode is the paper-faithful grid
# (ring 32, 8 MB chunks, the full tau x k x T_win axes) for real hardware.
CONFIG = dict(
    hosts=(128, 256, 512),
    ring=8 if QUICK else 32,
    chunk=512e3 if QUICK else 8e6,
    # ECMP collisions on the 1:2-oversubscribed core stretch the CCT
    # tail to ~5.2x the lockstep ideal at 128 hosts; 7x keeps every
    # lane finishing across seeds and Symphony on/off
    horizon_mult=7.0,
    n_seeds=1 if QUICK else 2,
    scaling_ticks=64 if QUICK else 256,
    scaling_lanes=8,
)


def _mesh_info():
    mesh = resolve_grid_mesh(devices="auto")
    n = 1 if mesh is None else int(mesh.devices.size)
    return n, [n]


def _pair_gains(cfgs, axes, med):
    """Pair each sym=True grid point with its sym=False twin (same values
    on every other axis) and report the best Symphony improvement."""
    names = list(axes)
    combos = knob_combos(axes)          # row-major, same order as knob_grid
    if "sym" not in names:
        return None
    si = names.index("sym")
    base = {tuple(c[:si] + c[si + 1:]): i
            for i, c in enumerate(combos) if not c[si]}
    best = None
    for i, c in enumerate(combos):
        if not c[si]:
            continue
        j = base.get(tuple(c[:si] + c[si + 1:]))
        if j is None or not (np.isfinite(med[i]) and np.isfinite(med[j])):
            continue
        gain = float(1 - med[i] / med[j])
        if best is None or gain > best["improvement"]:
            best = {"improvement": round(gain, 4),
                    "baseline_cct_s": round(float(med[j]), 4),
                    "symphony_cct_s": round(float(med[i]), 4)}
            best.update({n: v for n, v in zip(names, combos[i])
                         if n != "sym"})
    return best


def sweep_at(n_hosts: int) -> dict:
    """The registry sweep at one host count, sharded over all devices."""
    axes = sweep_axes_for(SCENARIO)
    built = build_scenario(SCENARIO, n_hosts=n_hosts, ring=CONFIG["ring"],
                           chunk=CONFIG["chunk"],
                           horizon_mult=CONFIG["horizon_mult"])
    cfgs = knob_grid(built.cfg, axes)
    seeds = list(range(CONFIG["n_seeds"]))
    lanes = len(cfgs) * len(seeds)
    n_dev, mesh_shape = _mesh_info()
    c0 = core_trace_count()
    t0 = time.time()
    res = run_grid(built.topo, built.wl, cfgs, seeds, built.routing,
                   devices="auto")
    wall = time.time() - t0
    compiles = core_trace_count() - c0
    cct = metrics.cct_seconds(res, built.wl, built.cfg)[..., 0]   # [K, S]
    med = np.nanmedian(cct, axis=1)
    lane_ticks = lanes * built.cfg.n_ticks
    return {
        "n_hosts": n_hosts,
        "n_links": built.topo.n_links,
        "n_ticks": built.cfg.n_ticks,
        "grid_points": len(cfgs),
        "seeds": len(seeds),
        "lanes": lanes,
        "devices": n_dev,
        "mesh_shape": mesh_shape,
        "grid_compiles": compiles,
        "wall_s": round(wall, 1),
        "lanes_per_s": round(lanes / wall, 4),
        "lane_ticks_per_s": round(lane_ticks / wall, 1),
        "lane_ticks_per_s_per_device": round(lane_ticks / wall / n_dev, 1),
        "unfinished_lanes": int(np.isnan(cct).sum()),
        "best_symphony": _pair_gains(cfgs, axes, med),
    }


def scaling_at(n_hosts: int) -> dict:
    """1 -> N-device lane-scaling on a short fixed-tick grid: the same
    compiled program dispatched unsharded, then sharded over all local
    devices."""
    built = build_scenario(SCENARIO, n_hosts=n_hosts, ring=CONFIG["ring"],
                           chunk=CONFIG["chunk"])
    n_ticks = CONFIG["scaling_ticks"]
    lanes = CONFIG["scaling_lanes"]
    base = built.cfg._replace(n_ticks=n_ticks, sym_on=True)
    cfgs = knob_grid(base, {"tau": tuple(
        np.round(np.linspace(0.1, 0.5, lanes), 3).tolist())})
    n_dev, _ = _mesh_info()

    def timed(devices):
        # warm-up dispatch compiles; the second dispatch is the measurement
        run_grid(built.topo, built.wl, cfgs, [0], built.routing,
                 devices=devices)
        t0 = time.time()
        run_grid(built.topo, built.wl, cfgs, [0], built.routing,
                 devices=devices)
        return time.time() - t0

    wall_1 = timed(1)
    wall_n = timed("auto") if n_dev > 1 else wall_1
    lane_ticks = lanes * n_ticks
    return {
        "n_hosts": n_hosts,
        "n_ticks": n_ticks,
        "lanes": lanes,
        "devices": n_dev,
        "wall_1dev_s": round(wall_1, 2),
        "wall_ndev_s": round(wall_n, 2),
        "speedup_1_to_n": round(wall_1 / wall_n, 2),
        "lane_ticks_per_s_1dev": round(lane_ticks / wall_1, 1),
        "lane_ticks_per_s_ndev": round(lane_ticks / wall_n, 1),
        "lane_ticks_per_s_per_device_ndev": round(
            lane_ticks / wall_n / n_dev, 1),
    }


def run() -> dict:
    out = {"sweep": {}, "scaling": {}}
    for h in CONFIG["hosts"]:
        out["scaling"][f"hosts_{h}"] = scaling_at(h)
        print(f"scaling @ {h} hosts:",
              json.dumps(out["scaling"][f"hosts_{h}"]), flush=True)
        out["sweep"][f"hosts_{h}"] = sweep_at(h)
        print(f"sweep @ {h} hosts:",
              json.dumps(out["sweep"][f"hosts_{h}"]), flush=True)
    return out


def _mode() -> str:
    return "quick" if QUICK else "full"


def write_bench(result) -> dict:
    data = {}
    if BENCH_FILE.exists():
        data = json.loads(BENCH_FILE.read_text())
        if data.get("schema") != BENCH_SCHEMA:
            data = {}
    data["schema"] = BENCH_SCHEMA
    n_dev, mesh_shape = _mesh_info()
    data[_mode()] = {
        "config": {k: list(v) if isinstance(v, tuple) else v
                   for k, v in CONFIG.items()},
        "host": {"cpu_count": os.cpu_count(),
                 "machine": platform.machine(),
                 "jax": jax.__version__,
                 "jax_backend": jax.default_backend(),
                 "device_count": jax.device_count(),
                 "mesh_shape": mesh_shape},
        "result": result,
    }
    BENCH_FILE.write_text(json.dumps(data, indent=1, sort_keys=True) + "\n")
    return data


def main(argv) -> int:
    t0 = time.time()
    res = run()
    res["_wall_s"] = round(time.time() - t0, 1)
    write_bench(res)
    print(json.dumps(res, indent=1))
    print(f"wrote {BENCH_FILE}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
