"""Paper Fig. 4: Symphony clamps step overlap; late-start recovery.

Targets: baseline max overlap 24-35; Symphony 3-6 across seeds; late-start
(enabled mid-run) stops further divergence; CCT reduced ~30% vs baseline.
"""
import numpy as np

from repro.core.netsim import metrics

from .common import QUICK, build_scenario, cached, run_seeds, seeds_for


def run():
    passes = 4 if QUICK else 6
    topo, wl, base_cfg, _ = build_scenario("table1_ring", passes=passes,
                                           horizon_mult=4.0)
    seeds = seeds_for(6, 3)

    out = {}
    for name, cfg in [
        ("baseline", base_cfg),
        ("symphony", base_cfg._replace(sym_on=True)),
        ("symphony_late_start",
         base_cfg._replace(sym_on=True,
                           sym_start_tick=base_cfg.n_ticks // 4)),
    ]:
        res = run_seeds(topo, wl, cfg, "ecmp", seeds)
        cct = metrics.cct_seconds(res, wl, cfg)[:, 0]
        ov = metrics.max_overlap(res, cfg)
        out[name] = {
            "cct_median_s": float(np.nanmedian(cct)),
            "overlap_min": int(ov.min()), "overlap_max": int(ov.max()),
            "overlap_median": float(np.median(ov)),
        }
    b, s = out["baseline"], out["symphony"]
    if b["cct_median_s"] and s["cct_median_s"]:
        out["cct_reduction"] = round(1 - s["cct_median_s"] / b["cct_median_s"], 3)
    out["ideal_s"] = metrics.ideal_cct(wl, 0, 10e9 / 8)
    return out


def bench():
    return cached("fig4_mitigation", run)
