"""Paper Fig. 4: Symphony clamps step overlap; late-start recovery.

Targets: baseline max overlap 24-35; Symphony 3-6 across seeds; late-start
(enabled mid-run) stops further divergence; CCT reduced ~30% vs baseline.
"""
import numpy as np

from repro.core.netsim import metrics

from .common import (QUICK, cached, default_params, run_seeds, seeds_for,
                     table1_topo, table1_workload)


def run():
    topo = table1_topo(32)
    passes = 4 if QUICK else 6
    wl = table1_workload(passes=passes)
    ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)
    horizon = int(ideal * 4.0 / 10e-6)
    seeds = seeds_for(6, 3)

    out = {}
    for name, cfg in [
        ("baseline", default_params(horizon)),
        ("symphony", default_params(horizon, sym=True)),
        ("symphony_late_start",
         default_params(horizon, sym=True,
                        sym_start_tick=horizon // 4)),
    ]:
        res = run_seeds(topo, wl, cfg, "ecmp", seeds)
        cct = metrics.cct_seconds(res, wl, cfg)[:, 0]
        ov = metrics.max_overlap(res, cfg)
        out[name] = {
            "cct_median_s": float(np.nanmedian(cct)),
            "overlap_min": int(ov.min()), "overlap_max": int(ov.max()),
            "overlap_median": float(np.median(ov)),
        }
    b, s = out["baseline"], out["symphony"]
    if b["cct_median_s"] and s["cct_median_s"]:
        out["cct_reduction"] = round(1 - s["cct_median_s"] / b["cct_median_s"], 3)
    out["ideal_s"] = metrics.ideal_cct(wl, 0, 10e9 / 8)
    return out


def bench():
    return cached("fig4_mitigation", run)
