"""Render the §Roofline table into EXPERIMENTS.md from dryrun_results.json."""
import json
import re
from pathlib import Path

from .roofline import rows

ROOT = Path(__file__).resolve().parents[1]


def table_md() -> str:
    lines = [
        "| cell | tC (ms) | tM (ms) | tX (ms) | bottleneck | useful | "
        "roofline frac | mem GiB (prod.) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows("single"):
        cell = r["cell"].rsplit("/", 1)[0]
        if "skipped" in r:
            lines.append(f"| {cell} | — | — | — | skipped | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {cell} | ERROR {r['error'][:40]} |")
            continue
        src = "" if r.get("cost_source") == "roofline" else " †"
        lines.append(
            f"| {cell}{src} | {r['t_compute_ms']} | {r['t_memory_ms']} | "
            f"{r['t_collective_ms']} | {r['bottleneck']} | "
            f"{r['useful_ratio']} | {r['roofline_frac']} | "
            f"{r['mem_gib']} |")
    lines.append("")
    lines.append("† cost terms from the production (scanned) lowering — "
                 "loop bodies counted once; treat as lower bounds.")
    return "\n".join(lines)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = re.sub(r"<!-- ROOFLINE_TABLE -->(.|\n)*?(?=\n## )",
                "<!-- ROOFLINE_TABLE -->\n\n" + table_md() + "\n\n",
                md, count=1)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print(table_md())


if __name__ == "__main__":
    main()
