"""Shared scenario registry + result caching for the paper benchmarks.

All network scenarios follow paper Table 1 defaults: 4 ToR x 4 spine,
10 Gbps, 32 nodes arranged as 4 parallel rings of 8 (the 8x4 logical 2-D),
chunk 8 MB, RED(50/100KB, 0.2), DCQCN-style CC, tau=0.25, T_win=100us,
k=0.01.  Larger scales (128 nodes = 32x4) follow the same pattern.

The declarative **scenario registry** is the single source of truth for
benchmark and test setups: each entry builds a ``Built(topo, wl, cfg,
routing)`` tuple from keyword overrides.  Fig-scripts and the system tests
both consume it::

    from benchmarks.common import build_scenario
    topo, wl, cfg, routing = build_scenario("table1_ring", passes=4)

Register new scenarios with the :func:`scenario` decorator.  Scenarios may
also declare **sweep axes** (named RuntimeKnobs dimensions such as ``tau``,
``k``, ``t_win_ticks``); ``run_scenario_grid`` crosses them and dispatches
the whole grid through ``simulate_grid`` — one compile for the entire
sweep, vmapped over knob points x seeds.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, NamedTuple, Sequence

import jax
import numpy as np

from repro.core.netsim import (SimParams, Topology, Workload, WorkloadBuilder,
                               grid_from_params, make_fat_tree,
                               make_leaf_spine, metrics, resolve_grid_mesh,
                               scale_for_hosts, simulate, simulate_grid,
                               simulate_seeds)
from repro.core.netsim.topology import DEFAULT_LINK_BPS as LINK_BPS

CACHE = Path(__file__).resolve().parent / ".cache.json"
QUICK = os.environ.get("BENCH_QUICK", "0") != "0"

# Bumped whenever the cache key scheme or result layout changes; older
# cache files are discarded wholesale instead of serving stale entries.
CACHE_SCHEMA = 3


def grid_devices():
    """Default multi-device dispatch for the benchmark layer, from the
    ``BENCH_DEVICES`` env var: ``"auto"`` = all local devices, an integer
    = that many, unset/empty/"1" = single-device dispatch (None)."""
    val = os.environ.get("BENCH_DEVICES", "").strip()
    if not val or val == "1":
        return None
    return "auto" if val == "auto" else int(val)


def device_fingerprint() -> str:
    """Backend + device/mesh configuration a result was produced under.

    Folded into every ``cached()`` key: single- and multi-device runs of
    the same scenario measure different dispatch paths (and wall clocks),
    so they must not collide in the result cache."""
    dev = grid_devices()
    mesh = resolve_grid_mesh(devices=dev)
    used = 1 if mesh is None else int(mesh.devices.size)
    return f"{jax.default_backend()}:{jax.device_count()}:grid{used}"


def _config_hash(config) -> str:
    blob = json.dumps(config, sort_keys=True, default=repr)
    return hashlib.sha1(blob.encode()).hexdigest()[:10]


def cached(name: str, fn, config=None):
    """Memoize a benchmark result in ``.cache.json``.

    The key folds in a hash of ``config`` — the overrides/sweep values the
    run depends on — plus the device/mesh fingerprint, so re-running a
    scenario with different parameters or on a different device
    configuration misses the cache instead of silently returning stale
    JSON.
    """
    cache = {}
    if CACHE.exists():
        data = json.loads(CACHE.read_text())
        if data.get("__schema__") == CACHE_SCHEMA:
            cache = data
    key = f"{name}{'@' + _config_hash(config) if config is not None else ''}" \
          f"::{device_fingerprint()}" \
          f"{'::quick' if QUICK else ''}"
    if key in cache:
        return cache[key]
    t0 = time.time()
    out = fn()
    out["_wall_s"] = round(time.time() - t0, 1)
    cache[key] = out
    cache["__schema__"] = CACHE_SCHEMA
    CACHE.write_text(json.dumps(cache, indent=1))
    return out


# --------------------------------------------------------------- registry
class Built(NamedTuple):
    """A fully-materialized scenario ready for ``simulate``."""
    topo: Topology
    wl: Workload
    cfg: SimParams
    routing: str = "ecmp"


# Named knob axes: how a sweep value lands in SimParams.  Every applier
# touches only RuntimeKnobs fields, so any cross-product of these axes
# stays a single compiled program under ``simulate_grid``.
KNOB_APPLIERS: dict[str, Callable[[SimParams, object], SimParams]] = {
    "sym": lambda c, v: c._replace(sym_on=bool(v)),
    "pq": lambda c, v: c._replace(pq_on=bool(v)),
    "tau": lambda c, v: c._replace(sym=c.sym._replace(tau=v)),
    "k": lambda c, v: c._replace(sym=c.sym._replace(k=v)),
    "alpha_max": lambda c, v: c._replace(sym=c.sym._replace(alpha_max=v)),
    "t_win_ticks": lambda c, v: c._replace(sym_win_ticks=int(v)),
    "sym_start_tick": lambda c, v: c._replace(sym_start_tick=int(v)),
    "red_pmax": lambda c, v: c._replace(red_pmax=v),
    "red_kmin": lambda c, v: c._replace(red_kmin=v),
    "red_kmax": lambda c, v: c._replace(red_kmax=v),
    "cc_rai": lambda c, v: c._replace(cc_rai=v),
    "cc_g": lambda c, v: c._replace(cc_g=v),
}


@dataclass(frozen=True)
class SweepAxis:
    """A declarative sweep dimension: a knob-axis name + default values."""
    knob: str                 # key into KNOB_APPLIERS
    values: tuple             # default grid values (full mode)
    quick: tuple | None = None  # reduced values under BENCH_QUICK

    def points(self) -> tuple:
        return self.quick if (QUICK and self.quick is not None) else self.values


@dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    build: Callable[..., Built]
    sweeps: tuple[SweepAxis, ...] = ()


SCENARIOS: dict[str, Scenario] = {}


def scenario(name: str, description: str = "",
             sweeps: Sequence[SweepAxis] = ()):
    """Register a scenario builder under ``name``, optionally with the
    declarative knob-sweep axes the paper evaluates it over."""
    def deco(fn):
        SCENARIOS[name] = Scenario(name, description, fn, tuple(sweeps))
        return fn
    return deco


def knob_combos(axes: dict[str, Sequence]) -> list[tuple]:
    """Row-major cross product of the axis values: the single source of
    truth for how grid point i maps back to axis values (``knob_grid``
    and any consumer labelling grid results must share this order)."""
    return list(itertools.product(*axes.values()))


def knob_grid(cfg: SimParams, axes: dict[str, Sequence]) -> list[SimParams]:
    """Cross-product of knob axes applied to a base config; point i
    corresponds to ``knob_combos(axes)[i]``."""
    for name in axes:
        if name not in KNOB_APPLIERS:
            raise KeyError(
                f"unknown knob axis {name!r}; have {sorted(KNOB_APPLIERS)}")
    cfgs = []
    for combo in knob_combos(axes):
        c = cfg
        for name, v in zip(axes, combo):
            c = KNOB_APPLIERS[name](c, v)
        cfgs.append(c)
    return cfgs


def sweep_axes_for(name: str) -> dict[str, tuple]:
    """The registered default sweep axes of a scenario (may be empty)."""
    return {ax.knob: ax.points() for ax in SCENARIOS[name].sweeps}


def run_grid(topo, wl, cfgs: Sequence[SimParams], seeds, routing="ecmp",
             chunk_knobs: int | None = None, devices="env", mesh=None, **bg):
    """Run a knob grid through the one-compile batched executor.

    ``devices``/``mesh`` shard the grid's lane axis across a 1-D device
    mesh (see ``simulate_grid``); the default ``"env"`` defers to the
    ``BENCH_DEVICES`` env var (unset = single-device dispatch).

    Returns a SimResult with leading ``[K, S]`` axes, K = len(cfgs).
    """
    if devices == "env":
        devices = grid_devices()
    struct, knobs = grid_from_params(list(cfgs))
    res = simulate_grid(topo, wl, struct, knobs, seeds, routing=routing,
                        chunk_knobs=chunk_knobs, devices=devices, mesh=mesh,
                        **bg)
    return jax.block_until_ready(res)


def run_scenario_grid(name: str, axes: dict[str, Sequence] | None = None,
                      seeds=(0,), chunk_knobs: int | None = None,
                      devices="env", mesh=None, **overrides):
    """Build a registered scenario and sweep its knob axes in one compile.

    ``axes`` defaults to the scenario's registered sweep axes; ``devices``
    / ``mesh`` shard the grid lanes across devices.  Returns ``(built,
    cfgs, result)`` where ``cfgs[i]`` describes grid point i and
    ``result`` carries ``[K, S]`` leading axes.
    """
    built = build_scenario(name, **overrides)
    axes = sweep_axes_for(name) if axes is None else axes
    cfgs = knob_grid(built.cfg, axes)
    res = run_grid(built.topo, built.wl, cfgs, seeds, routing=built.routing,
                   chunk_knobs=chunk_knobs, devices=devices, mesh=mesh)
    return built, cfgs, res


def build_scenario(name: str, **overrides) -> Built:
    """Materialize a registered scenario with keyword overrides."""
    try:
        sc = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; have {list_scenarios()}")
    return sc.build(**overrides)


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)


def _horizon_cfg(wl, mult: float = 4.0, dt: float = 10e-6,
                 **kw) -> SimParams:
    """SimParams sized to a multiple of the job-0 lockstep lower bound."""
    ideal = metrics.ideal_cct(wl, 0, LINK_BPS)
    return SimParams(n_ticks=int(ideal * mult / dt), dt=dt, window=64, **kw)


# ------------------------------------------------- Table-1 building blocks
def table1_topo(n_hosts: int = 32):
    if n_hosts == 32:
        return make_leaf_spine(32, 4, 4)
    return scale_for_hosts(n_hosts)


def table1_workload(n_hosts: int = 32, ring: int = 8, chunk: float = 8e6,
                    passes: int = 8, barrier: bool = False,
                    compute_gap: float = 0.0,
                    chunk_schedule=None):
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(n_hosts)), ring_size=ring,
                   chunk_bytes=chunk_schedule if chunk_schedule is not None
                   else chunk,
                   passes=passes, barrier=barrier, compute_gap=compute_gap)
    return b.build()


@scenario("table1_ring",
          "Paper Table-1: 2-tier leaf-spine, parallel 1-D ring allreduce",
          sweeps=(
              SweepAxis("sym", (False, True)),
              SweepAxis("tau", (0.1, 0.25, 0.5), quick=(0.25,)),
              SweepAxis("k", (1e-3, 1e-2, 1e-1), quick=(1e-2,)),
          ))
def _table1_ring(n_hosts: int = 32, ring: int = 8, chunk: float = 8e6,
                 passes: int = 6, barrier: bool = False,
                 compute_gap: float = 0.0, chunk_schedule=None,
                 horizon_mult: float = 4.0, sym: bool = False,
                 share_policy: str = "proportional") -> Built:
    topo = table1_topo(n_hosts)
    wl = table1_workload(n_hosts, ring, chunk, passes, barrier, compute_gap,
                         chunk_schedule)
    return Built(topo, wl, _horizon_cfg(wl, horizon_mult, sym_on=sym,
                                        share_policy=share_policy))


@scenario("table1_2d",
          "Paper §4.6: 2-D ring collective on the Table-1 fabric",
          sweeps=(
              SweepAxis("k", (1e-4, 1e-3, 1e-2, 1e-1),
                        quick=(1e-3, 1e-2, 1e-1)),
          ))
def _table1_2d(n_hosts: int = 32, d0: int = 8, chunk: float = 8e6,
               passes: int = 3, horizon_mult: float = 5.0,
               sym: bool = False) -> Built:
    topo = table1_topo(n_hosts)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(n_hosts)), ring_size=d0, passes=passes,
                   chunk_bytes=chunk, dims=(d0, n_hosts // d0))
    wl = b.build()
    return Built(topo, wl, _horizon_cfg(wl, horizon_mult, sym_on=sym))


@scenario("two_flow_fig9",
          "Paper Fig. 9 hardware prototype: two flows, one ToR egress port")
def _two_flow_fig9(delay_a: float = 0.25, size: float = 1e9,
                   sym: bool = False) -> Built:
    # hosts 0,1 send to host 2: both flows share the ToR egress port
    # (acc_down of host 2), exactly the prototype's single-port contention.
    # Same job, flow B tagged one step ahead (step in the UDP sport, §4.7):
    # B is the outpacing flow, A the lagging one.
    topo = make_leaf_spine(4, 2, 2)
    b = WorkloadBuilder()
    b.add_chain_job(pairs=[(0, 2), (1, 2)], steps=1, chunk_bytes=size,
                    step_offsets=[0, 1], flow_starts=[delay_a, 0.0])
    wl = b.build()
    t_end = 3.2 * (size / 1.25e9) + delay_a + 0.2
    cfg = SimParams(n_ticks=int(t_end / 20e-6), dt=20e-6, window=8,
                    sym_on=sym)
    return Built(topo, wl, cfg, routing="balanced")


@scenario("multi_tenant_pair",
          "Paper Fig. 7a/b: two co-located jobs, job B delayed")
def _multi_tenant_pair(n_hosts: int = 64, ring: int = 8, chunk: float = 8e6,
                       passes: int = 3, delay: float = 0.1,
                       sym: bool = False) -> Built:
    topo = table1_topo(n_hosts)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(n_hosts)), ring_size=ring,
                   chunk_bytes=chunk, passes=passes, barrier=False)
    b.add_ring_job(hosts=list(range(n_hosts)), ring_size=ring,
                   chunk_bytes=chunk, passes=passes, barrier=False,
                   start_time=delay)
    wl = b.build()
    horizon = int((0.15 * passes + 0.8) / 10e-6)
    return Built(topo, wl, SimParams(n_ticks=horizon, window=64, sym_on=sym))


@scenario("fat_tree_ring",
          "3-tier multi-pod fat-tree, inter-pod interleaved ring allreduce")
def _fat_tree_ring(n_pods: int = 2, tors_per_pod: int = 2,
                   spines_per_pod: int = 2, hosts_per_tor: int = 4,
                   n_cores: int | None = None,
                   core_oversubscription: float = 1.0,
                   ring: int | None = None, chunk: float = 4e6,
                   passes: int = 2, barrier: bool = False,
                   horizon_mult: float = 6.0, sym: bool = False) -> Built:
    topo = make_fat_tree(n_pods, tors_per_pod, spines_per_pod, hosts_per_tor,
                         n_cores, core_oversubscription=core_oversubscription)
    n = topo.n_hosts
    ring = n // 2 if ring is None else ring
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(n)), ring_size=ring, chunk_bytes=chunk,
                   passes=passes, barrier=barrier)
    wl = b.build()
    return Built(topo, wl, _horizon_cfg(wl, horizon_mult, sym_on=sym))


@scenario("fat_tree_halving_doubling",
          "3-tier fat-tree, recursive halving-doubling allreduce")
def _fat_tree_hd(n_pods: int = 2, tors_per_pod: int = 2,
                 spines_per_pod: int = 2, hosts_per_tor: int = 4,
                 core_oversubscription: float = 1.0, chunk: float = 4e6,
                 passes: int = 1, horizon_mult: float = 6.0,
                 sym: bool = False) -> Built:
    topo = make_fat_tree(n_pods, tors_per_pod, spines_per_pod, hosts_per_tor,
                         core_oversubscription=core_oversubscription)
    b = WorkloadBuilder()
    b.add_halving_doubling_job(hosts=list(range(topo.n_hosts)),
                               chunk_bytes=chunk, passes=passes)
    wl = b.build()
    return Built(topo, wl, _horizon_cfg(wl, horizon_mult, sym_on=sym))


def multipod_topo(n_hosts: int, hosts_per_tor: int = 8, tors_per_pod: int = 4,
                  spines_per_pod: int = 4, n_cores: int = 8,
                  core_oversubscription: float = 2.0) -> Topology:
    """3-tier multi-pod FatTree scaled to ``n_hosts`` (32 hosts/pod by
    default: 128 -> 4 pods, 256 -> 8, 512 -> 16), with a 1:2 core tier
    matching the paper's oversubscribed multi-pod interconnects (§4.1)."""
    per_pod = hosts_per_tor * tors_per_pod
    if n_hosts % per_pod:
        raise ValueError(f"hosts ({n_hosts}) must divide evenly over "
                         f"{per_pod}-host pods")
    return make_fat_tree(n_hosts // per_pod, tors_per_pod, spines_per_pod,
                         hosts_per_tor, n_cores,
                         core_oversubscription=core_oversubscription)


@scenario("fat_tree_multipod",
          "128-512 host 3-tier multi-pod FatTree, inter-pod interleaved "
          "rings — the Table-2/Fig-8-at-scale sweep fabric",
          sweeps=(
              SweepAxis("sym", (False, True)),
              SweepAxis("tau", (0.1, 0.25, 0.5), quick=(0.25,)),
              SweepAxis("k", (1e-3, 1e-2, 1e-1), quick=(1e-2,)),
              SweepAxis("t_win_ticks", (5, 10, 20), quick=(5,)),
          ))
def _fat_tree_multipod(n_hosts: int = 128, ring: int = 32,
                       chunk: float = 2e6, passes: int = 1,
                       barrier: bool = False, horizon_mult: float = 4.0,
                       sym: bool = False, deploy: str = "tor",
                       core_oversubscription: float = 2.0,
                       coarse: bool = True) -> Built:
    """The 512-host-class sweep scenario: parallel ``ring``-size rings
    striped across pods, coarse 20us ticks by default (control-loop
    windows rescaled to keep T_win = 100us / 40us CC epochs) so dense
    knob grids stay affordable at 512 hosts."""
    topo = multipod_topo(n_hosts,
                         core_oversubscription=core_oversubscription)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(n_hosts)), ring_size=ring,
                   chunk_bytes=chunk, passes=passes, barrier=barrier)
    wl = b.build()
    extra = dict(sym_win_ticks=5, cc_epoch_ticks=2) if coarse else {}
    cfg = _horizon_cfg(wl, horizon_mult, dt=20e-6 if coarse else 10e-6,
                       sym_on=sym, deploy=deploy, **extra)
    return Built(topo, wl, cfg)


@scenario("tenant_churn",
          "Continuous multi-tenant replay over the multipod fabric: "
          "Poisson tenant arrivals/departures plus a dependency-triggered "
          "follow-on job (the online control plane's serving workload)",
          sweeps=(
              SweepAxis("sym", (False, True)),
              SweepAxis("tau", (0.1, 0.25, 0.5), quick=(0.25,)),
          ))
def _tenant_churn(n_hosts: int = 64, ring: int = 8, chunk: float = 2e6,
                  passes: int = 2, rate_hz: float = 150.0,
                  churn_horizon_s: float = 0.04, max_tenants: int = 4,
                  trigger_delay: float = 2e-3, churn_seed: int = 0,
                  horizon_mult: float = 6.0, sym: bool = False,
                  deploy: str = "tor",
                  core_oversubscription: float = 2.0) -> Built:
    """Job 0 is a long-lived tenant; job 1 is dependency-triggered (starts
    when job 0 completes its first collective, cf. CCL_Simulator's policy
    rules); the remaining ring-sized host groups serve a Poisson stream of
    short-lived tenants.  All arrivals are lowered to traced arrays, so
    churn grids still run under the one-compile grid/shard executors."""
    topo = multipod_topo(n_hosts,
                         core_oversubscription=core_oversubscription)
    groups = [list(range(g * ring, (g + 1) * ring))
              for g in range(n_hosts // ring)]
    if len(groups) < 3:
        raise ValueError("tenant_churn needs >= 3 ring-sized host groups")
    b = WorkloadBuilder()
    base = b.add_ring_job(hosts=groups[0], ring_size=ring, chunk_bytes=chunk,
                          passes=passes, barrier=False)
    follow = b.add_ring_job(hosts=groups[1], ring_size=ring,
                            chunk_bytes=chunk, passes=passes, barrier=False)
    b.set_trigger(follow, after_job=base, collectives=1,
                  delay=trigger_delay)
    b.add_poisson_churn(groups[2:], rate_hz=rate_hz,
                        horizon_s=churn_horizon_s, ring_size=ring,
                        chunk_bytes=chunk / 4, passes=1, seed=churn_seed,
                        max_jobs=max(1, max_tenants // 2 if QUICK
                                     else max_tenants))
    wl = b.build()
    cfg = _horizon_cfg(wl, horizon_mult, dt=20e-6, sym_on=sym,
                       deploy=deploy, sym_win_ticks=5, cc_epoch_ticks=2)
    return Built(topo, wl, cfg)


@scenario("hierarchical_tor",
          "Hierarchical allreduce: intra-ToR rings + inter-ToR leader ring")
def _hierarchical_tor(n_hosts: int = 32, n_tors: int = 4, n_spines: int = 4,
                      chunk: float = 8e6, passes: int = 2,
                      horizon_mult: float = 6.0, sym: bool = False) -> Built:
    topo = make_leaf_spine(n_hosts, n_tors, n_spines)
    b = WorkloadBuilder()
    b.add_hierarchical_job(hosts=list(range(n_hosts)),
                           group_size=topo.hosts_per_tor,
                           chunk_bytes=chunk, passes=passes)
    wl = b.build()
    return Built(topo, wl, _horizon_cfg(wl, horizon_mult, sym_on=sym))


# ------------------------------------------------------------ run helpers
def default_params(n_ticks: int, sym: bool = False, **kw) -> SimParams:
    return SimParams(n_ticks=n_ticks, window=64, sym_on=sym, **kw)


def kernel_tuning() -> dict:
    """Fused-kernel tuning knobs for the benchmark layer, overridable via
    env (``BENCH_SEGSUM``, ``BENCH_BLK``, ``BENCH_TICK_WINDOW``) so perf
    sweeps over the kernel configuration need no code edits.  Returns
    ``SimParams`` override kwargs; the defaults are the committed
    BENCH_netsim.json trajectory configuration (scatter segsum, untiled,
    tick_window=5 — windows amortize state HBM round-trips, see
    ``roofline.netsim_tick_tiled``)."""
    segsum = os.environ.get("BENCH_SEGSUM", "scatter")
    blk = os.environ.get("BENCH_BLK", "")
    tw = os.environ.get("BENCH_TICK_WINDOW", "5")
    return {"segsum": segsum,
            "blk": int(blk) if blk else None,
            "tick_window": int(tw) if tw else 1}


def params_for_seconds(horizon_s: float, sym: bool = False,
                       coarse: bool = False, **kw) -> SimParams:
    """coarse=True runs at 20 us ticks (halves cost for multi-second JCT
    scenarios; control-loop windows rescaled to keep T_win=100us, 40us CC
    epochs)."""
    dt = 20e-6 if coarse else 10e-6
    extra = dict(sym_win_ticks=5, cc_epoch_ticks=2) if coarse else {}
    extra.update(kw)
    return SimParams(n_ticks=int(horizon_s / dt) // 20 * 20, dt=dt,
                     window=64, sym_on=sym, **extra)


def run_one(topo, wl, cfg, routing="ecmp", seed=0, **bg):
    res = simulate(topo, wl, cfg, routing=routing, seed=seed, **bg)
    return jax.block_until_ready(res)


def run_scenario(name: str, seed: int = 0, **overrides):
    """Build and run a registered scenario; returns (built, result)."""
    built = build_scenario(name, **overrides)
    return built, run_one(built.topo, built.wl, built.cfg,
                          routing=built.routing, seed=seed)


def summarize(res, wl, cfg, job=0):
    cct = metrics.cct_seconds(res, wl, cfg)
    return {
        "cct_s": float(cct[job]) if np.isfinite(cct[job]) else None,
        "max_overlap": int(metrics.max_overlap(res, cfg, job)),
        "ideal_s": metrics.ideal_cct(wl, job, LINK_BPS),
    }


def seeds_for(n_full: int, n_quick: int = 3):
    return list(range(n_quick if QUICK else n_full))


def run_seeds(topo, wl, cfg, routing, seeds, devices="env", mesh=None, **bg):
    """Batched multi-seed run (vmap), seed lanes sharded like grid lanes."""
    if devices == "env":
        devices = grid_devices()
    res = simulate_seeds(topo, wl, cfg, routing, seeds, devices=devices,
                        mesh=mesh, **bg)
    return jax.block_until_ready(res)
