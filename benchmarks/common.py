"""Shared scenario builders + result caching for the paper benchmarks.

All network scenarios follow paper Table 1 defaults: 4 ToR x 4 spine,
10 Gbps, 32 nodes arranged as 4 parallel rings of 8 (the 8x4 logical 2-D),
chunk 8 MB, RED(50/100KB, 0.2), DCQCN-style CC, tau=0.25, T_win=100us,
k=0.01.  Larger scales (128 nodes = 32x4) follow the same pattern.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.netsim import (SimParams, WorkloadBuilder, make_leaf_spine,
                               metrics, scale_for_hosts, simulate,
                               simulate_seeds)

CACHE = Path(__file__).resolve().parent / ".cache.json"
QUICK = os.environ.get("BENCH_QUICK", "0") != "0"


def cached(name: str, fn):
    cache = json.loads(CACHE.read_text()) if CACHE.exists() else {}
    key = f"{name}{'::quick' if QUICK else ''}"
    if key in cache:
        return cache[key]
    t0 = time.time()
    out = fn()
    out["_wall_s"] = round(time.time() - t0, 1)
    cache[key] = out
    CACHE.write_text(json.dumps(cache, indent=1))
    return out


def table1_topo(n_hosts: int = 32):
    if n_hosts == 32:
        return make_leaf_spine(32, 4, 4)
    return scale_for_hosts(n_hosts)


def table1_workload(n_hosts: int = 32, ring: int = 8, chunk: float = 8e6,
                    passes: int = 8, barrier: bool = False,
                    compute_gap: float = 0.0,
                    chunk_schedule=None):
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(n_hosts)), ring_size=ring,
                   chunk_bytes=chunk_schedule if chunk_schedule is not None
                   else chunk,
                   passes=passes, barrier=barrier, compute_gap=compute_gap)
    return b.build()


def default_params(n_ticks: int, sym: bool = False, **kw) -> SimParams:
    return SimParams(n_ticks=n_ticks, window=64, sym_on=sym, **kw)


def params_for_seconds(horizon_s: float, sym: bool = False,
                       coarse: bool = False, **kw) -> SimParams:
    """coarse=True runs at 20 us ticks (halves cost for multi-second JCT
    scenarios; control-loop windows rescaled to keep T_win=100us, 40us CC
    epochs)."""
    dt = 20e-6 if coarse else 10e-6
    extra = dict(sym_win_ticks=5, cc_epoch_ticks=2) if coarse else {}
    extra.update(kw)
    return SimParams(n_ticks=int(horizon_s / dt) // 20 * 20, dt=dt,
                     window=64, sym_on=sym, **extra)


def run_one(topo, wl, cfg, routing="ecmp", seed=0, **bg):
    res = simulate(topo, wl, cfg, routing=routing, seed=seed, **bg)
    return jax.block_until_ready(res)


def summarize(res, wl, cfg, job=0):
    cct = metrics.cct_seconds(res, wl, cfg)
    return {
        "cct_s": float(cct[job]) if np.isfinite(cct[job]) else None,
        "max_overlap": int(metrics.max_overlap(res, cfg, job)),
        "ideal_s": metrics.ideal_cct(wl, job, 10e9 / 8),
    }


def seeds_for(n_full: int, n_quick: int = 3):
    return list(range(n_quick if QUICK else n_full))


def run_seeds(topo, wl, cfg, routing, seeds, **bg):
    """Batched multi-seed run (vmap)."""
    res = simulate_seeds(topo, wl, cfg, routing, seeds, **bg)
    return jax.block_until_ready(res)
