"""Paper Fig. 6: compute-bound Transformer JCT vs computation-reduction
ratio (simulating faster accelerators).  Target: normalized JCT approaches
~0.7 of baseline as compute shrinks 64x."""
import numpy as np

from repro.core.netsim import metrics

from .common import (QUICK, cached, params_for_seconds, run_seeds,
                     seeds_for, table1_topo)
from .table2_e2e import TRANSFORMER_BUCKETS, _jobs


def run():
    hosts, ring = 32, 8
    topo = table1_topo(hosts)
    iters = 2
    seeds = seeds_for(5, 2)
    ratios = [1, 8, 64] if QUICK else [1, 4, 16, 64]
    base_gap = 0.4
    out = {}
    for r in ratios:
        gap = base_gap / r / len(TRANSFORMER_BUCKETS)
        wl = _jobs(hosts, TRANSFORMER_BUCKETS, gap, iters, ring)
        ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)
        cfg_b = params_for_seconds(min(ideal * 3 + 0.2, 4.0), coarse=True)
        cfg_s = params_for_seconds(min(ideal * 3 + 0.2, 4.0), sym=True,
                                   coarse=True)
        b = run_seeds(topo, wl, cfg_b, "ecmp", seeds)
        s = run_seeds(topo, wl, cfg_s, "ecmp", seeds)
        jb = np.nanmean(metrics.cct_seconds(b, wl, cfg_b)[:, 0])
        js = np.nanmean(metrics.cct_seconds(s, wl, cfg_s)[:, 0])
        out[f"reduction_{r}x"] = {
            "normalized_jct": round(float(js / jb), 4)
            if np.isfinite(jb) and np.isfinite(js) else None,
        }
    return out


def bench():
    return cached("fig6_commratio", run)
