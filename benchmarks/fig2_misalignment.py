"""Paper Fig. 2: step misalignment under different network conditions.

Scenarios: Theoretical (lockstep bound), Baseline (ECMP), Load Imbalance
(1.13x skew on one uplink, static balanced routing), Transient Congestion
(light square-wave background, static balanced routing).

Paper targets: baseline overlap snowballs to ~30 and CCT inflates ~60%;
light perturbations reach ~10 overlap / ~7% CCT inflation.
"""
import numpy as np

from .common import QUICK, build_scenario, cached, run_one, summarize


def run():
    passes = 4 if QUICK else 6
    topo, wl, cfg, _ = build_scenario("table1_ring", passes=passes,
                                      horizon_mult=4.0)
    from repro.core.netsim import metrics
    ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)

    rows = {}
    rows["theoretical"] = {"cct_s": ideal, "max_overlap": 1, "ideal_s": ideal}

    # baseline ECMP
    rows["baseline_ecmp"] = summarize(run_one(topo, wl, cfg, "ecmp", 3),
                                      wl, cfg)
    # load imbalance 1.13x on one uplink
    bg = np.zeros(topo.n_links)
    up0 = topo.uplink(1, 0)
    bg[up0] = 0.13 * topo.link_cap[up0]
    rows["load_imbalance_1.13"] = summarize(
        run_one(topo, wl, cfg, "balanced", 3, bg_base=bg), wl, cfg)
    # transient congestion: 50% line-rate bursts, 30% duty, 10 ms period
    amp = np.zeros(topo.n_links)
    for t, s in [(0, 1), (2, 3)]:
        amp[topo.uplink(t, s)] = 0.5 * topo.link_cap[up0]
    rows["congestion_transient"] = summarize(
        run_one(topo, wl, cfg, "balanced", 3, bg_amp=amp, bg_period=10e-3,
                bg_duty=0.3), wl, cfg)

    for k, v in rows.items():
        if v["cct_s"]:
            v["cct_inflation"] = round(v["cct_s"] / ideal - 1, 3)
    return rows


def bench():
    return cached("fig2_misalignment", run)
