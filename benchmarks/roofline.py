"""Roofline analysis.

Two independent sections:

* :func:`rows` — the LLM dry-run roofline from ``dryrun_results.json``
  (produce it with ``python -m repro.launch.dryrun``; the table is
  rendered into the repo docs by ``benchmarks/render_experiments.py``).
  The artifact is optional — when absent this section degrades to a
  skip message instead of crashing.
* :func:`netsim_tick_traffic` — an analytic bytes-moved model of the
  netsim engine's tick hot path, comparing the staged XLA engine
  (every stage intermediate round-trips HBM) against the fused
  ``kernels/netsim_tick`` Pallas kernel (only true tick I/O touches
  HBM).  This is the memory-bound headroom the fusion buys on a real
  accelerator; on the CPU CI host the kernel runs in interpret mode and
  the win is *not* observable in wall clock.

Dry-run cost terms per (arch x shape x mesh), all in seconds per step:
  t_compute    = HLO_FLOPs_total / (chips * 197e12)       [bf16 peak, v5e]
  t_memory     = HLO_bytes_total / (chips * 819e9)
  t_collective = wire_bytes_total / (chips * 50e9)        [ICI per link]

cost_analysis() reports the per-device program, so *_total = per_device *
chips and the chips cancel: the terms below use per-device values directly.
Also reports MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "dryrun_results.json"
SKIP_MSG = (f"{RESULTS.name} not found — run `python -m repro.launch.dryrun` "
            "to produce the dry-run artifacts (optional; the netsim section "
            "below does not need them)")

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def model_flops_per_step(rec) -> float:
    """6 * N(_active) * tokens for train (fwd+bwd); 2 * N * tokens for
    inference shapes."""
    n = rec["active_params"]
    shape = rec["shape"]
    if shape.startswith("train"):
        tokens = 256 * 4096
        mult = 6.0
    elif shape.startswith("prefill"):
        tokens = 32 * 32768
        mult = 2.0
    elif shape == "decode_32k":
        tokens = 128
        mult = 2.0
    else:
        tokens = 1
        mult = 2.0
    return mult * n * tokens


def rows(mesh: str = "single"):
    """Cost terms prefer the loop-free '/roofline' records (exact trip
    counts); memory always comes from the production '/single' lowering.
    Returns [] (after printing the skip message) when the dry-run
    artifact is absent."""
    if not RESULTS.exists():
        print(f"roofline: skipped — {SKIP_MSG}")
        return []
    data = json.loads(RESULTS.read_text())
    out = []
    for key, rec in sorted(data.items()):
        if not key.endswith(f"/{mesh}"):
            continue
        if rec.get("skipped"):
            out.append({"cell": key, "skipped": rec["skipped"]})
            continue
        if not rec.get("ok"):
            out.append({"cell": key, "error": rec.get("error", "?")[:100]})
            continue
        rl = data.get(key.rsplit("/", 1)[0] + "/roofline")
        src = rl if (mesh == "single" and rl and rl.get("ok")
                     and not rl.get("skipped")) else rec
        chips = src["chips"]
        t_c = src["flops_per_device"] / PEAK
        t_m = src["bytes_per_device"] / HBM
        t_x = src["wire_bytes_per_device"] / ICI
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops_per_step(src)
        hlo_total = src["flops_per_device"] * chips
        out.append({
            "cell": key,
            "t_compute_ms": round(t_c * 1e3, 2),
            "t_memory_ms": round(t_m * 1e3, 2),
            "t_collective_ms": round(t_x * 1e3, 2),
            "bottleneck": dom,
            "model_flops": mf,
            "useful_ratio": round(mf / hlo_total, 3) if hlo_total else None,
            "roofline_frac": round(
                max(t_c, 1e-12) / max(t_c, t_m, t_x), 3),
            "mem_gib": round(rec["memory"]["per_device_total"] / 2**30, 2),
            "fits_v5e": rec["memory"]["fits_v5e"],
            "cost_source": "roofline" if src is rl else "production",
        })
    return out


# ------------------------------------------------- netsim tick traffic
def _tick_arrays(F, W, H, L, D, J, P, SEG):
    """Array inventory of one engine tick (elements, bytes/elem).

    ``io``: the tick's true inputs/outputs — state read + state/metric
    written; this is what the fused kernel moves.  ``intermediates``:
    arrays the staged XLA engine additionally materializes between stage
    ops (each is written by one op and read by the next, so it crosses
    HBM twice)."""
    FW, FWH, L1, DJ = F * W, F * W * H, L + 1, (D + 1) * J
    io = {
        "state_inst": (3 * FW, 4),           # step_of, sent, rate
        "state_flow": (F, 4),                # done_upto
        "state_link": (L1, 4),               # q
        "state_sym": (5 * DJ, 4),            # stepmin/psnwin/alpha/cnt/cntop
        "static_routes": (F * H + F * P * H + F, 4),
        "static_links": (4 * L1, 4),         # cap, dom, bg_base, bg_amp
        "inst_consts": (6 * FW, 4),          # job/flow/sps/phase/nph/off
        "chunk_sched": (J * SEG, 4),
        "out_routes": (FWH, 4),              # iroute handed back
        "out_inst": (FW, 4),                 # eff
        "out_link": (3 * L1, 4),             # offered, q, p_red
        "out_sym": (5 * DJ, 4),
    }
    intermediates = {
        "view_scalars": (4 * FW, 4),         # iseg, ichunk, iwire, ipsn
        "view_flags": (4 * FW, 1),           # occupied/retired/complete/active
        "view_paths": (3 * FWH, 4),          # iroute, idom, dj
        "share_masked": (2 * FW, 4),         # w_rate, eff scale
        "share_hops": (2 * FWH, 4),          # per-hop repeat + s_l gather
        "share_links": (2 * L1, 4),          # offered, per-link scale
        "queue_links": (2 * L1, 4),          # q', p_red
        "sym_hops": (4 * FWH, 4),            # wire4, psn4, pkts4, sm gather
        "sym_flags": (3 * FWH, 1),           # act4, send4, done4
        "sym_rows": (5 * DJ, 4),             # scattered row updates
    }
    return io, intermediates


def netsim_tick_traffic():
    """Analytic HBM bytes per tick, staged XLA vs fused Pallas, on the
    Table-1 scenario dims — plus the implied memory-bound ticks/sec
    ceiling at v5e HBM bandwidth."""
    from repro.core.netsim import build_static
    from repro.core.netsim.simulator import wl_arrays
    from repro.core.netsim.stages import make_ctx

    from .common import build_scenario

    topo, wl, cfg, _ = build_scenario("table1_ring", passes=2)
    st = build_static(topo, wl, "ecmp", 0, dt=cfg.dt, deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    P = int(st.path_table.shape[1])
    SEG = int(ctx.wl.chunk_sched.shape[1])
    io, inter = _tick_arrays(ctx.F, ctx.W, ctx.H, ctx.L, ctx.D, ctx.J,
                             P, SEG)
    io_b = sum(n * w for n, w in io.values())
    inter_b = 2 * sum(n * w for n, w in inter.values())  # write + read back
    staged = io_b + inter_b
    return {
        "scenario": "table1_ring",
        "dims": {"F": ctx.F, "W": ctx.W, "H": ctx.H, "L": ctx.L,
                 "D": ctx.D, "J": ctx.J},
        "bytes_per_tick_fused": io_b,
        "bytes_per_tick_staged": staged,
        "fusion_traffic_ratio": round(staged / io_b, 2),
        "t_memory_us_staged": round(staged / HBM * 1e6, 3),
        "t_memory_us_fused": round(io_b / HBM * 1e6, 3),
        "ticks_per_s_hbm_ceiling_staged": round(HBM / staged),
        "ticks_per_s_hbm_ceiling_fused": round(HBM / io_b),
        "note": "analytic model at v5e HBM bandwidth; interpret-mode "
                "pallas on the CPU CI host does not realize this win",
    }


# Per-instance arrays streamed block-by-block through VMEM by the tiled
# kernel (BlockSpec over the flat [FW] axis); everything else stays
# resident across grid steps (constant index maps -> fetched once).
_BLOCK_STREAMED_IN = ("state_inst", "inst_consts")
_BLOCK_STREAMED_OUT = ("out_routes", "out_inst")
_TILED_SWEEPS = 4   # kernel.TILED_SWEEPS: jobmin/offered/eff/finalize


def netsim_tick_tiled(blk: int = 256, tick_window: int = 5):
    """Analytic HBM/VMEM model of the PR-8 kernel shapes, next to the
    PR-6 monolithic number (``netsim_tick_traffic``):

    * **tiled onehot grid kernel** — per-instance operands stream through
      VMEM one ``blk``-row block at a time (re-fetched once per sweep,
      so x TILED_SWEEPS), while link/Symphony/static arrays stay VMEM-
      resident across grid steps; reports the per-block VMEM working set
      that replaces the whole-[FW] residency of the monolithic kernel.
    * **multi-tick window kernel** — the full engine state round-trips
      HBM once per ``tick_window`` ticks instead of once per tick, so
      state bytes/tick amortize to 1/tick_window.
    """
    from repro.core.netsim import build_static
    from repro.core.netsim.simulator import wl_arrays
    from repro.core.netsim.stages import make_ctx

    from .common import build_scenario

    topo, wl, cfg, _ = build_scenario("table1_ring", passes=2)
    st = build_static(topo, wl, "ecmp", 0, dt=cfg.dt, deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    P = int(st.path_table.shape[1])
    SEG = int(ctx.wl.chunk_sched.shape[1])
    io, inter = _tick_arrays(ctx.F, ctx.W, ctx.H, ctx.L, ctx.D, ctx.J,
                             P, SEG)
    FW = ctx.F * ctx.W
    nb = -(-FW // blk)
    io_b = sum(n * w for n, w in io.values())
    inter_b = 2 * sum(n * w for n, w in inter.values())
    staged = io_b + inter_b

    stream_in = sum(n * w for k, (n, w) in io.items()
                    if k in _BLOCK_STREAMED_IN)
    stream_out = sum(n * w for k, (n, w) in io.items()
                     if k in _BLOCK_STREAMED_OUT)
    resident = io_b - stream_in - stream_out
    # streamed inputs re-fetched every sweep; resident arrays + outputs
    # cross HBM once per tick
    tiled = _TILED_SWEEPS * stream_in + resident + stream_out
    vmem_block = (stream_in + stream_out) // FW * blk + resident

    # window kernel: whole state + static in/out once per window; the
    # per-tick sample write is a few [J]+scalar rows (negligible)
    window = io_b / tick_window

    return {
        "scenario": "table1_ring",
        "blk": blk, "n_blocks": nb, "tick_window": tick_window,
        "bytes_per_tick_staged": staged,
        "bytes_per_tick_fused_monolithic": io_b,          # the PR 6 number
        "bytes_per_tick_tiled": tiled,
        "bytes_per_tick_windowed": round(window),
        "vmem_working_set_monolithic_kib": round(io_b / 1024, 1),
        "vmem_working_set_tiled_kib": round(vmem_block / 1024, 1),
        "fusion_ratio_monolithic": round(staged / io_b, 2),
        "fusion_ratio_tiled": round(staged / tiled, 2),
        "fusion_ratio_windowed": round(staged / window, 2),
        "ticks_per_s_hbm_ceiling_tiled": round(HBM / tiled),
        "ticks_per_s_hbm_ceiling_windowed": round(HBM / window),
        "note": "tiled: streamed blocks re-fetched once per sweep, "
                "resident arrays fetched once (Mosaic skips re-fetch on "
                "unchanged block index); windowed: state HBM round-trips "
                "amortized 1/tick_window (blk + tick_window combine by "
                "normalizing to the window kernel — params.plan_tiling)",
    }


def netsim_tick_gatherfree(blk: int = 256):
    """Analytic model of the gather-free tiled kernel: the packed
    per-instance route tables (``params.pack_route_tables``) replace every
    in-kernel gather with BlockSpec-streamed dense slabs + iota-selects.

    Costs: the table slabs — ``[blk, SEG]`` chunk schedules, two
    ``[blk, P, H]`` ECMP candidate planes, ``[blk]`` path counts, plus the
    instance-expanded done column — cross HBM once per sweep per block
    like the other streamed operands.  Buys: the resident gather tables
    (routes, path_table, n_paths, chunk_sched, link_dom) drop out of the
    kernel entirely, and the lowering carries **zero** gathers and
    scatters (Mosaic-lowerable; the scalar-prefetched per-block valid
    counts keep block shapes static so next-block table DMA overlaps
    compute).  Net: more streamed bytes than the gather-based tiling, in
    exchange for a lowering Mosaic can compile at all — the relevant
    ceiling comparison is against the staged engine, not the
    interpret-only gather-based tiling.
    """
    from repro.core.netsim import build_static
    from repro.core.netsim.simulator import wl_arrays
    from repro.core.netsim.stages import make_ctx

    from .common import build_scenario

    topo, wl, cfg, _ = build_scenario("table1_ring", passes=2)
    st = build_static(topo, wl, "ecmp", 0, dt=cfg.dt, deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    P = int(st.path_table.shape[1])
    SEG = int(ctx.wl.chunk_sched.shape[1])
    F, W, H, L, D, J = ctx.F, ctx.W, ctx.H, ctx.L, ctx.D, ctx.J
    io, inter = _tick_arrays(F, W, H, L, D, J, P, SEG)
    FW, L1 = F * W, L + 1
    nb = -(-FW // blk)
    io_b = sum(n * w for n, w in io.values())
    staged = io_b + 2 * sum(n * w for n, w in inter.values())

    stream_base = sum(n * w for k, (n, w) in io.items()
                      if k in _BLOCK_STREAMED_IN)
    stream_out = sum(n * w for k, (n, w) in io.items()
                     if k in _BLOCK_STREAMED_OUT)
    # packed-table slabs: chunk [FW,SEG], cand+cand_dom [FW,P,H] x2,
    # n_paths [FW], plus done_upto expanded [F] -> [FW]
    table_stream = (FW * SEG + 2 * FW * P * H + 2 * FW) * 4
    # resident gather tables the slabs replace: routes/path_table/n_paths
    # (static_routes), chunk_sched, link_dom, and the [F] done column
    removed = (F * H + F * P * H + F + J * SEG + L1 + F) * 4
    resident = io_b - stream_base - stream_out - removed
    stream_in = stream_base + table_stream
    tiled = _TILED_SWEEPS * stream_in + resident + stream_out
    vmem_block = (stream_in + stream_out) // FW * blk + resident

    return {
        "scenario": "table1_ring",
        "blk": blk, "n_blocks": nb, "ecmp_paths": P,
        "table_stream_bytes_per_tick": _TILED_SWEEPS * table_stream,
        "removed_gather_table_bytes": removed,
        "bytes_per_tick_staged": staged,
        "bytes_per_tick_gatherfree": tiled,
        "vmem_working_set_kib": round(vmem_block / 1024, 1),
        "fusion_ratio_gatherfree": round(staged / tiled, 2),
        "ticks_per_s_hbm_ceiling_gatherfree": round(HBM / tiled),
        "stablehlo": {"gather": 0, "scatter": 0},
        "note": "table slabs stream once per sweep per block via "
                "BlockSpec; scalar-prefetched per-block valid counts "
                "overlap next-block table DMA with compute; zero "
                "gather/scatter is CI-gated "
                "(test_tiled_onehot_stablehlo_scatter_free_and_gather_free)",
    }


def bench():
    out = {"netsim_tick": netsim_tick_traffic(),
           "netsim_tick_tiled": netsim_tick_tiled(),
           "netsim_tick_gatherfree": netsim_tick_gatherfree()}
    if RESULTS.exists():
        out["rows"] = rows("single")
    else:
        out["dryrun_skipped"] = SKIP_MSG
    return out


if __name__ == "__main__":
    print(json.dumps(bench(), indent=1))
