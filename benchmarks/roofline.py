"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all in seconds per step:
  t_compute    = HLO_FLOPs_total / (chips * 197e12)       [bf16 peak, v5e]
  t_memory     = HLO_bytes_total / (chips * 819e9)
  t_collective = wire_bytes_total / (chips * 50e9)        [ICI per link]

cost_analysis() reports the per-device program, so *_total = per_device *
chips and the chips cancel: the terms below use per-device values directly.
Also reports MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
import json
from pathlib import Path

from .common import cached

RESULTS = Path(__file__).resolve().parents[1] / "dryrun_results.json"

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def model_flops_per_step(rec) -> float:
    """6 * N(_active) * tokens for train (fwd+bwd); 2 * N * tokens for
    inference shapes."""
    n = rec["active_params"]
    shape = rec["shape"]
    if shape.startswith("train"):
        tokens = 256 * 4096
        mult = 6.0
    elif shape.startswith("prefill"):
        tokens = 32 * 32768
        mult = 2.0
    elif shape == "decode_32k":
        tokens = 128
        mult = 2.0
    else:
        tokens = 1
        mult = 2.0
    return mult * n * tokens


def rows(mesh: str = "single"):
    """Cost terms prefer the loop-free '/roofline' records (exact trip
    counts); memory always comes from the production '/single' lowering."""
    data = json.loads(RESULTS.read_text())
    out = []
    for key, rec in sorted(data.items()):
        if not key.endswith(f"/{mesh}"):
            continue
        if rec.get("skipped"):
            out.append({"cell": key, "skipped": rec["skipped"]})
            continue
        if not rec.get("ok"):
            out.append({"cell": key, "error": rec.get("error", "?")[:100]})
            continue
        rl = data.get(key.rsplit("/", 1)[0] + "/roofline")
        src = rl if (mesh == "single" and rl and rl.get("ok")
                     and not rl.get("skipped")) else rec
        chips = src["chips"]
        t_c = src["flops_per_device"] / PEAK
        t_m = src["bytes_per_device"] / HBM
        t_x = src["wire_bytes_per_device"] / ICI
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops_per_step(src)
        hlo_total = src["flops_per_device"] * chips
        out.append({
            "cell": key,
            "t_compute_ms": round(t_c * 1e3, 2),
            "t_memory_ms": round(t_m * 1e3, 2),
            "t_collective_ms": round(t_x * 1e3, 2),
            "bottleneck": dom,
            "model_flops": mf,
            "useful_ratio": round(mf / hlo_total, 3) if hlo_total else None,
            "roofline_frac": round(
                max(t_c, 1e-12) / max(t_c, t_m, t_x), 3),
            "mem_gib": round(rec["memory"]["per_device_total"] / 2**30, 2),
            "fits_v5e": rec["memory"]["fits_v5e"],
            "cost_source": "roofline" if src is rl else "production",
        })
    return out


def bench():
    return {"rows": rows("single")}
