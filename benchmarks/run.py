"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call = scenario wall
time; derived = the headline metric next to the paper's target).

Set BENCH_QUICK=1 for reduced seeds/horizons; results cache in
benchmarks/.cache.json so repeated invocations are cheap.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    import importlib
    specs = [
        ("fig2_misalignment",
         lambda r: f"baseline_overlap={r['baseline_ecmp']['max_overlap']};"
                   f"infl={r['baseline_ecmp'].get('cct_inflation')}",
         "paper: overlap~30 +60%CCT"),
        ("fig4_mitigation",
         lambda r: f"overlap {r['baseline']['overlap_max']}->"
                   f"{r['symphony']['overlap_max']};"
                   f"cct_red={r.get('cct_reduction')}",
         "paper: 24-35 -> 3-6 | ~30%"),
        ("fig5_cct_cdf",
         lambda r: f"vs_base={r.get('reduction_vs_baseline')};"
                   f"vs_pq={r.get('reduction_vs_pq')}",
         "paper: ~22% | ~19%"),
        ("table2_e2e",
         lambda r: ";".join(f"{k}={v['improvement']}"
                            for k, v in r.items()
                            if isinstance(v, dict) and "improvement" in v),
         "paper: vgg .50-.54 resnet .21-.24 transformer ~0"),
        ("fig6_commratio",
         lambda r: ";".join(f"{k}={v['normalized_jct']}"
                            for k, v in r.items() if isinstance(v, dict)),
         "paper: ->~0.7 @64x"),
        ("fig7_multitenant",
         lambda r: f"span_red={r.get('span_reduction')};" +
                   ";".join(f"{k}={v.get('jct_improvement')}"
                            for k, v in r.items() if k.startswith('scale_')),
         "paper: .015@16 -> ~.17@64"),
        ("fig8_sweeps",
         lambda r: ";".join(f"{k}={list(v.values())[0]}"
                            for k, v in r.items() if isinstance(v, dict)),
         "paper: grows w/ imbalance+chunk; k sweet 1e-3..1e-2"),
        ("fig9_two_flow",
         lambda r: ";".join(
             f"{k}:A-{v['A_reduction']}/B+{v['B_cost']}"
             for k, v in r.items() if isinstance(v, dict)),
         "paper: A -.12 B +.02 @0.5s"),
        ("netsim_perf",
         lambda r: f"ticks/s={r['ticks_per_s_single']};"
                   f"vmap8_speedup={r['vmap_speedup']}",
         "sim throughput"),
    ]
    print("name,us_per_call,derived")
    for name, extract, note in specs:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            r = mod.bench()
            wall = r.get("_wall_s", 0.0)
            print(f"{name},{wall * 1e6:.0f},{extract(r)} [{note}]")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}")
        sys.stdout.flush()
    # roofline table from the dry-run artifacts (no simulation)
    try:
        from . import roofline
        for row in roofline.rows("single"):
            if "skipped" in row:
                print(f"roofline.{row['cell']},0,skipped:{row['skipped'][:50]}")
            elif "error" in row:
                print(f"roofline.{row['cell']},0,ERROR:{row['error']}")
            else:
                print(f"roofline.{row['cell']},0,"
                      f"bottleneck={row['bottleneck']};"
                      f"tC={row['t_compute_ms']}ms;tM={row['t_memory_ms']}ms;"
                      f"tX={row['t_collective_ms']}ms;"
                      f"useful={row['useful_ratio']}")
    except FileNotFoundError:
        print("roofline,nan,run `python -m repro.launch.dryrun` first")


if __name__ == "__main__":
    main()
