"""Paper Fig. 7: multi-tenant workloads.

(a/b) two identical co-located jobs (B starts 500 ms after A): Symphony keeps
aggregate throughput high and shrinks the final-step span (tail).
(c) random job arrivals at mixed scales: improvement grows with job scale.
"""
import jax
import numpy as np

from repro.core.netsim import WorkloadBuilder, metrics

from .common import (QUICK, build_scenario, cached, default_params,
                     run_grid, seeds_for, table1_topo)


def run():
    out = {}
    # ---- two-job co-location (registry scenario, Fig. 7a/b)
    hosts = 32 if QUICK else 64
    passes = 2 if QUICK else 3
    topo, wl, base_cfg, _ = build_scenario("multi_tenant_pair",
                                           n_hosts=hosts, passes=passes)
    seeds = seeds_for(10, 3)
    variants = [("baseline", base_cfg),
                ("symphony", base_cfg._replace(sym_on=True))]
    # sym_on is a RuntimeKnob: both variants dispatch as ONE 2-point grid
    # (one compile, lanes sharded across devices when configured); each
    # variant's [S, ...] slice then feeds the unchanged metrics code.
    gres = run_grid(topo, wl, [c for _, c in variants], seeds, "ecmp")
    for i, (name, cfg) in enumerate(variants):
        res = jax.tree.map(lambda x: x[i], gres)
        cct = metrics.cct_seconds(res, wl, cfg)
        spans = [metrics.flow_span_seconds(res, wl, cfg, job=j)
                 for j in (0, 1)]
        out[f"two_job_{name}"] = {
            "jobA_cct_mean_s": float(np.nanmean(cct[:, 0])),
            "jobB_cct_mean_s": float(np.nanmean(cct[:, 1])),
            "final_step_span_mean_s": float(np.mean(
                [np.mean(s) for s in spans])),
        }
    b, s = out["two_job_baseline"], out["two_job_symphony"]
    out["span_reduction"] = round(
        1 - s["final_step_span_mean_s"] / b["final_step_span_mean_s"], 3)

    # ---- scale sweep: co-located jobs of increasing size
    scales = [16, 32] if QUICK else [16, 32, 64]
    for n in scales:
        topo = table1_topo(max(n * 2, 32))
        b2 = WorkloadBuilder()
        b2.add_ring_job(hosts=list(range(n)), ring_size=min(8, n),
                        chunk_bytes=8e6, passes=2, barrier=False)
        b2.add_ring_job(hosts=list(range(n, 2 * n)), ring_size=min(8, n),
                        chunk_bytes=4e6, passes=3, barrier=False,
                        start_time=0.02)
        wl2 = b2.build()
        horizon = int(0.9 / 10e-6)
        cfg_b = default_params(horizon)
        cfg_s = default_params(horizon, sym=True)
        res2 = run_grid(topo, wl2, [cfg_b, cfg_s], seeds, "ecmp")
        cct2 = metrics.cct_seconds(res2, wl2, cfg_b)[..., 0]   # [2, S]
        jb, js = cct2[0], cct2[1]
        out[f"scale_{n}"] = {
            "jct_improvement": round(1 - np.nanmedian(js) / np.nanmedian(jb), 4)
            if np.isfinite(np.nanmedian(jb)) else None}
    return out


def bench():
    return cached("fig7_multitenant", run)
