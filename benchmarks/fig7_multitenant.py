"""Paper Fig. 7: multi-tenant workloads.

(a/b) two identical co-located jobs (B starts 500 ms after A): Symphony keeps
aggregate throughput high and shrinks the final-step span (tail).
(c) random job arrivals at mixed scales: improvement grows with job scale.

Streaming mode (``run_streaming``): the ``tenant_churn`` scenario —
Poisson tenant arrivals/departures plus a dependency-triggered follow-on
job — replayed continuously through the online control plane
(``SimController.step``), one window at a time with Symphony retunable
mid-flight.  The serving-story counterpart of the one-shot (a-c) runs.
"""
import jax
import numpy as np

from repro.core.netsim import SimController, WorkloadBuilder, metrics
from repro.core.netsim.simulator import I32MAX

from .common import (QUICK, build_scenario, cached, default_params,
                     run_grid, seeds_for, table1_topo)


def run():
    out = {}
    # ---- two-job co-location (registry scenario, Fig. 7a/b)
    hosts = 32 if QUICK else 64
    passes = 2 if QUICK else 3
    topo, wl, base_cfg, _ = build_scenario("multi_tenant_pair",
                                           n_hosts=hosts, passes=passes)
    seeds = seeds_for(10, 3)
    variants = [("baseline", base_cfg),
                ("symphony", base_cfg._replace(sym_on=True))]
    # sym_on is a RuntimeKnob: both variants dispatch as ONE 2-point grid
    # (one compile, lanes sharded across devices when configured); each
    # variant's [S, ...] slice then feeds the unchanged metrics code.
    gres = run_grid(topo, wl, [c for _, c in variants], seeds, "ecmp")
    for i, (name, cfg) in enumerate(variants):
        res = jax.tree.map(lambda x: x[i], gres)
        cct = metrics.cct_seconds(res, wl, cfg)
        spans = [metrics.flow_span_seconds(res, wl, cfg, job=j)
                 for j in (0, 1)]
        out[f"two_job_{name}"] = {
            "jobA_cct_mean_s": float(np.nanmean(cct[:, 0])),
            "jobB_cct_mean_s": float(np.nanmean(cct[:, 1])),
            "final_step_span_mean_s": float(np.mean(
                [np.mean(s) for s in spans])),
        }
    b, s = out["two_job_baseline"], out["two_job_symphony"]
    out["span_reduction"] = round(
        1 - s["final_step_span_mean_s"] / b["final_step_span_mean_s"], 3)

    # ---- scale sweep: co-located jobs of increasing size
    scales = [16, 32] if QUICK else [16, 32, 64]
    for n in scales:
        topo = table1_topo(max(n * 2, 32))
        b2 = WorkloadBuilder()
        b2.add_ring_job(hosts=list(range(n)), ring_size=min(8, n),
                        chunk_bytes=8e6, passes=2, barrier=False)
        b2.add_ring_job(hosts=list(range(n, 2 * n)), ring_size=min(8, n),
                        chunk_bytes=4e6, passes=3, barrier=False,
                        start_time=0.02)
        wl2 = b2.build()
        horizon = int(0.9 / 10e-6)
        cfg_b = default_params(horizon)
        cfg_s = default_params(horizon, sym=True)
        res2 = run_grid(topo, wl2, [cfg_b, cfg_s], seeds, "ecmp")
        cct2 = metrics.cct_seconds(res2, wl2, cfg_b)[..., 0]   # [2, S]
        jb, js = cct2[0], cct2[1]
        out[f"scale_{n}"] = {
            "jct_improvement": round(1 - np.nanmedian(js) / np.nanmedian(jb), 4)
            if np.isfinite(np.nanmedian(jb)) else None}
    return out


def run_streaming():
    """Continuous multi-tenant replay through the step() control plane."""
    from repro.core.netsim import core_trace_count

    over = dict(max_tenants=2, horizon_mult=4.0) if QUICK else {}
    topo, wl, cfg, routing = build_scenario("tenant_churn", **over)
    window = cfg.record_every * (4 if QUICK else 8)
    max_windows = max(1, cfg.n_ticks // window)
    out = {"tenants": int(wl.n_jobs), "window_ticks": window,
           "triggered_jobs": int(np.sum(np.asarray(wl.trig_job) >= 0))}
    for name, sym in (("baseline", False), ("symphony", True)):
        ctl = SimController(topo, wl, cfg._replace(sym_on=sym),
                            window_ticks=window, routing=routing, seed=0)
        c0 = core_trace_count()
        alpha_peak, windows = 0.0, 0
        obs = None
        for _ in range(max_windows):
            _, obs = ctl.step()
            windows += 1
            alpha_peak = max(alpha_peak, obs.stats.alpha_max)
            if obs.done:
                break
        jf = np.asarray(ctl.state.engine.job_finish)
        fin = jf != I32MAX
        # cct measured from each tenant's nominal arrival; triggered jobs
        # count their dependency wait (start_time 0), like the paper's JCT
        cct = (jf - np.asarray(wl.start_time) / cfg.dt) * cfg.dt
        out[name] = {
            "windows": windows,
            "engine_compiles": core_trace_count() - c0,
            "jobs_finished": int(fin.sum()),
            "mean_tenant_cct_s": round(float(cct[fin].mean()), 4)
            if fin.any() else None,
            "alpha_peak": round(alpha_peak, 1),
        }
    b, s = out["baseline"], out["symphony"]
    if b["mean_tenant_cct_s"] and s["mean_tenant_cct_s"]:
        out["cct_improvement"] = round(
            1 - s["mean_tenant_cct_s"] / b["mean_tenant_cct_s"], 4)
    return out


def bench():
    out = cached("fig7_multitenant", run)
    out["streaming"] = cached("fig7_streaming", run_streaming)
    return out
