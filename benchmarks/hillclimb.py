"""§Perf hillclimbing driver for the selected cells.

Each variant re-lowers the cell with a change and reports the roofline
terms; results accumulate in hillclimb_results.json and are written up in
EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell mamba2 --variant ssd_bf16
  PYTHONPATH=src python -m benchmarks.hillclimb --cell nemo15 --variant zero1
  PYTHONPATH=src python -m benchmarks.hillclimb --cell ring  --variant bf16

The ``netsim`` cell hillclimbs Symphony's control knobs (tau x k, T_win)
over the Table-1 scenario through the batched grid executor: the whole
candidate grid is ONE compile of the engine (``simulate_grid``), so a
variant's cost is dominated by device time, not re-tracing.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell netsim --variant tau_k
  PYTHONPATH=src python -m benchmarks.hillclimb --cell netsim --variant t_win
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import re
import time
from pathlib import Path

import jax

OUT = Path(__file__).resolve().parents[1] / "hillclimb_results.json"

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def _terms(res):
    return {
        "t_compute_ms": round(res["flops_per_device"] / PEAK * 1e3, 2),
        "t_memory_ms": round(res["bytes_per_device"] / HBM * 1e3, 2),
        "t_collective_ms": round(res["wire_bytes_per_device"] / ICI * 1e3, 2),
    }


def measure_cell(arch, shape, flag_fn=None, overrides=None):
    from repro import flags
    from repro.launch.dryrun import run_cell
    if flag_fn:
        flag_fn()
    try:
        res = run_cell(arch, shape, multi_pod=False, roofline=True)
        if overrides:
            # re-run with policy overrides plumbed through the roofline path
            pass
        return _terms(res) | {"compile_s": res["compile_s"]}
    finally:
        flags.set_ssd_bf16(False)


def measure_cell_overrides(arch, shape, policy_overrides, flag_fn=None):
    """Roofline measurement with policy overrides (depth-extrapolated)."""
    from repro import flags
    from repro.launch.dryrun import _measure, collective_bytes, _full_params
    from repro.launch.mesh import make_production_mesh
    from repro.configs import registry
    from repro.models import build_model
    flags.set_roofline(True)
    if flag_fn:
        flag_fn()
    try:
        mesh = make_production_mesh()
        cfg = registry.get_config(arch)
        model = build_model(cfg)
        period = getattr(model, "period", 1)
        G = cfg.num_layers // period
        ov = {"scan_layers": False, "accum": 1}
        ov.update(policy_overrides or {})
        t0 = time.time()
        _, c1 = _measure(arch, shape, mesh, ov, period)
        _, c2 = _measure(arch, shape, mesh, ov, 2 * period)

        def costs(comp):
            ca = comp.cost_analysis()
            colls = collective_bytes(comp.as_text())
            return (float(ca.get("flops", 0)),
                    float(ca.get("bytes accessed", 0)),
                    sum(d["wire"] for d in colls.values()))

        f1, b1, w1 = costs(c1)
        f2, b2, w2 = costs(c2)

        def ext(v1, v2):
            return v1 + (v2 - v1) * (G - 1) if v2 > v1 > 0 else v2 / 2 * G

        return {
            "t_compute_ms": round(ext(f1, f2) / PEAK * 1e3, 2),
            "t_memory_ms": round(ext(b1, b2) / HBM * 1e3, 2),
            "t_collective_ms": round(ext(w1, w2) / ICI * 1e3, 2),
            "compile_s": round(time.time() - t0, 1),
        }
    finally:
        flags.set_roofline(False)
        flags.set_ssd_bf16(False)


def measure_ring(dtype="float32", mode="ring", channels=4):
    """Wire bytes of the explicit-ring grad-sync train step (danube,
    16x16 mesh, manual over data)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import flags
    from repro.config import ParallelConfig, TrainConfig
    from repro.configs import registry
    from repro.launch.dryrun import collective_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.models.params import abstract_tree
    from repro.optim.adamw import OptState
    from repro.parallel.sharding import make_rules
    from repro.runtime.train import make_train_step

    flags.set_ring_sync_dtype(dtype)
    try:
        mesh = make_production_mesh()
        cfg = registry.get_config("h2o_danube_3_4b")
        par = ParallelConfig(grad_sync=mode, ring_buckets=channels,
                             remat="block", scan_layers=True)
        rules = make_rules()
        model = build_model(cfg, par, mesh=mesh, rules=rules)
        tcfg = TrainConfig(global_batch=256, seq_len=4096)
        step = make_train_step(model, cfg, tcfg, par, mesh)
        p_abs = model.abstract_params()
        spec_tree = model.param_spec()
        f32 = abstract_tree(spec_tree, rules, mesh)
        recast = lambda t, d: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, d, sharding=x.sharding), t)
        opt = OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       m=recast(f32, jnp.float32), v=recast(f32, jnp.float32),
                       master=recast(f32, jnp.float32))
        from jax.sharding import NamedSharding
        tok = jax.ShapeDtypeStruct((256, 4096), jnp.int32,
                                   sharding=NamedSharding(mesh, P("data", None)))
        batch = {"tokens": tok, "labels": tok}
        t0 = time.time()
        with mesh:
            compiled = step.lower(p_abs, opt, batch).compile()
        colls = collective_bytes(compiled.as_text())
        wire = sum(d["wire"] for d in colls.values())
        return {
            "wire_gb_per_device": round(wire / 1e9, 3),
            "t_collective_ms": round(wire / ICI * 1e3, 2),
            "collectives": {k: {"count": v["count"],
                                "wire_gb": round(v["wire"] / 1e9, 3)}
                            for k, v in colls.items()},
            "compile_s": round(time.time() - t0, 1),
        }
    finally:
        flags.set_ring_sync_dtype("float32")


def measure_netsim_grid(axes: dict, seeds=4, devices="env"):
    """Hillclimb Symphony knobs on the Table-1 scenario via simulate_grid.

    Returns the best grid point by median CCT plus the grid's wall time
    and engine compile count (must be 1: the grid is a single program).
    ``devices`` shards the candidate lanes across a device mesh (default
    defers to BENCH_DEVICES; this module forces 512 virtual CPU devices,
    so ``devices="auto"`` spreads the grid wide).
    """
    import numpy as np
    from benchmarks.common import (build_scenario, grid_devices, knob_combos,
                                   knob_grid, run_grid)
    from repro.core.netsim import (core_trace_count, metrics,
                                   resolve_grid_mesh)

    topo, wl, base, routing = build_scenario("table1_ring", passes=2)
    cfgs = knob_grid(base._replace(sym_on=True), axes)
    mesh = resolve_grid_mesh(
        devices=grid_devices() if devices == "env" else devices)
    c0 = core_trace_count()
    t0 = time.time()
    res = run_grid(topo, wl, cfgs, list(range(seeds)), routing,
                   devices=devices)
    wall = time.time() - t0
    compiles = core_trace_count() - c0
    cct = metrics.cct_seconds(res, wl, base)[..., 0]      # [K, S]
    med = np.nanmedian(cct, axis=1)
    order = np.argsort(np.where(np.isfinite(med), med, np.inf))
    best = int(order[0])
    axis_names = list(axes)
    combos = knob_combos(axes)    # same row-major order as knob_grid
    return {
        "grid_points": len(cfgs), "seeds": seeds,
        "device_count": 1 if mesh is None else int(mesh.devices.size),
        "grid_wall_s": round(wall, 1), "engine_compiles": compiles,
        "best": dict(zip(axis_names, combos[best])) |
                {"cct_median_s": round(float(med[best]), 4)},
        "cct_median_by_point": {
            "/".join(f"{v:g}" for v in combos[i]): round(float(med[i]), 4)
            for i in order[:8] if np.isfinite(med[i])},
    }


def measure_netsim_online(window_recs: int = 8, max_windows: int = 600,
                          seed: int = 0):
    """Online tuner over the ``step()`` control plane: retune tau/k every
    window against the live per-window observations (alternating-coordinate
    hillclimb on aggregate delivered throughput), next to the offline grid
    cell.  The windowed engine compiles ONCE; every retune is a free knob
    update (``engine_compiles`` must be 1 across ALL windows of BOTH the
    tuned and the fixed-knob rollout)."""
    import numpy as np
    from benchmarks.common import build_scenario
    from repro.core.netsim import SimController, core_trace_count
    from repro.core.netsim.simulator import I32MAX

    topo, wl, base, routing = build_scenario("table1_ring", passes=2)
    cfg = base._replace(sym_on=True)
    window = cfg.record_every * window_recs

    def rollout(policy):
        ctl = SimController(topo, wl, cfg, window_ticks=window,
                            routing=routing, seed=seed)
        action, obs = None, None
        for i in range(max_windows):
            _, obs = ctl.step(action)
            if obs.done:
                break
            action = policy(i, obs) if policy else None
        jf = np.asarray(ctl.state.engine.job_finish)
        cct = float(jf[0]) * cfg.dt if jf[0] != I32MAX else None
        return ctl, obs, cct, i + 1

    knobs = {"tau": 0.25, "k": 0.01}
    bounds = {"tau": (0.02, 0.8), "k": (1e-4, 0.3)}
    factor = {"tau": 1.5, "k": 2.0}
    direction = {"tau": -1, "k": 1}
    prev_obj = -np.inf
    trace = []

    def tuner(i, obs):
        nonlocal prev_obj
        obj = float(np.sum(obs.stats.tput))
        name = "tau" if i % 2 == 0 else "k"
        if obj < prev_obj:          # last move hurt: reverse that coordinate
            direction[name] *= -1
        prev_obj = obj
        lo, hi = bounds[name]
        knobs[name] = float(np.clip(
            knobs[name] * factor[name] ** direction[name], lo, hi))
        trace.append({"window": i, "tput_sum": round(obj / 1e9, 3),
                      "alpha_max": round(obs.stats.alpha_max, 1),
                      **{k: round(v, 4) for k, v in knobs.items()}})
        return dict(knobs)

    c0 = core_trace_count()
    t0 = time.time()
    _, _, cct_online, w_online = rollout(tuner)
    _, _, cct_fixed, w_fixed = rollout(None)
    wall = time.time() - t0
    compiles = core_trace_count() - c0
    return {
        "window_ticks": window,
        "windows_online": w_online, "windows_fixed": w_fixed,
        "engine_compiles": compiles,
        "wall_s": round(wall, 1),
        "final_knobs": {k: round(v, 4) for k, v in knobs.items()},
        "cct_online_s": round(cct_online, 4) if cct_online else None,
        "cct_fixed_s": round(cct_fixed, 4) if cct_fixed else None,
        "online_vs_fixed": round(cct_fixed / cct_online, 3)
        if cct_online and cct_fixed else None,
        "tuner_trace_head": trace[:6],
    }


VARIANTS = {
    ("mamba2", "baseline"): lambda: measure_cell("mamba2_130m", "train_4k"),
    ("mamba2", "ssd_bf16"): lambda: measure_cell(
        "mamba2_130m", "train_4k",
        flag_fn=lambda: __import__("repro.flags", fromlist=["x"]).set_ssd_bf16(True)),
    ("nemo15", "baseline"): lambda: measure_cell_overrides(
        "nemotron_4_15b", "train_4k", {}),
    ("nemo15", "zero1"): lambda: measure_cell_overrides(
        "nemotron_4_15b", "train_4k", {"fsdp": False, "zero1": True}),
    ("ring", "f32"): lambda: measure_ring("float32"),
    ("ring", "bf16"): lambda: measure_ring("bfloat16"),
    ("ring", "psum"): lambda: measure_ring("float32", mode="xla"),
    ("ring", "bf16_c8"): lambda: measure_ring("bfloat16", channels=8),
    ("netsim", "tau_k"): lambda: measure_netsim_grid(
        {"tau": (0.1, 0.2, 0.25, 0.4, 0.5), "k": (1e-3, 3e-3, 1e-2, 3e-2)}),
    ("netsim", "t_win"): lambda: measure_netsim_grid(
        {"t_win_ticks": (5, 10, 20, 40), "k": (3e-3, 1e-2)}),
    ("netsim", "red"): lambda: measure_netsim_grid(
        {"red_pmax": (0.1, 0.2, 0.4), "red_kmin": (25e3, 50e3, 75e3)}),
    ("netsim", "online"): lambda: measure_netsim_online(),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", required=True)
    args = ap.parse_args()
    res = VARIANTS[(args.cell, args.variant)]()
    data = json.loads(OUT.read_text()) if OUT.exists() else {}
    data[f"{args.cell}/{args.variant}"] = res
    OUT.write_text(json.dumps(data, indent=1))
    print(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
