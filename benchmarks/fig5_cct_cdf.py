"""Paper Fig. 5: CCT distribution for Ring AllReduce — baseline vs strict
priority queueing (PQ) vs Symphony.

Targets: Symphony ~22% lower than baseline and ~19% lower than PQ at the
median; PQ suffers from starvation-induced oscillation.
"""
import numpy as np

from repro.core.netsim import metrics

from .common import (QUICK, cached, default_params, run_seeds, seeds_for,
                     table1_topo, table1_workload)


def run():
    topo = table1_topo(32)
    passes = 2 if QUICK else 3
    wl = table1_workload(passes=passes)
    ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)
    horizon = int(ideal * 4.5 / 10e-6)
    seeds = seeds_for(12, 4)

    out = {}
    for name, cfg in [
        ("baseline", default_params(horizon)),
        ("pq", default_params(horizon, pq_on=True)),
        ("symphony", default_params(horizon, sym=True)),
    ]:
        res = run_seeds(topo, wl, cfg, "ecmp", seeds)
        cct = metrics.cct_seconds(res, wl, cfg)[:, 0]
        out[name] = {
            "cct_median_s": float(np.nanmedian(cct)),
            "cct_p90_s": float(np.nanpercentile(cct, 90)),
            "n_unfinished": int(np.isnan(cct).sum()),
        }
    for other in ("baseline", "pq"):
        if out[other]["cct_median_s"]:
            out[f"reduction_vs_{other}"] = round(
                1 - out["symphony"]["cct_median_s"] /
                out[other]["cct_median_s"], 3)
    return out


def bench():
    return cached("fig5_cct_cdf", run)
