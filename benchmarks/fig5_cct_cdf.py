"""Paper Fig. 5: CCT distribution for Ring AllReduce — baseline vs strict
priority queueing (PQ) vs Symphony.

Targets: Symphony ~22% lower than baseline and ~19% lower than PQ at the
median; PQ suffers from starvation-induced oscillation.

All three variants differ only in RuntimeKnobs (the ``pq_on`` / ``sym_on``
gates), so the whole figure — 3 variants x all seeds — dispatches through
``simulate_grid`` as ONE compiled program.
"""
import numpy as np

from repro.core.netsim import metrics, resolve_grid_mesh

from .common import (QUICK, build_scenario, cached, grid_devices, run_grid,
                     seeds_for)

# single source of truth for the run parameters AND the cache key: editing
# one without the other is exactly the stale-cache bug cached() guards
# against.  QUICK keeps the CI smoke cheap (one pass, half-size chunks,
# 2 seeds) with ~45% horizon headroom so seed variance can't NaN the gate.
CONFIG = dict(passes=1 if QUICK else 3,
              chunk=4e6 if QUICK else 8e6,
              horizon_mult=4.0 if QUICK else 4.5,
              n_seeds=len(seeds_for(12, 2)))


def run():
    topo, wl, base_cfg, _ = build_scenario(
        "table1_ring", passes=CONFIG["passes"], chunk=CONFIG["chunk"],
        horizon_mult=CONFIG["horizon_mult"])
    seeds = list(range(CONFIG["n_seeds"]))

    variants = [
        ("baseline", base_cfg),
        ("pq", base_cfg._replace(pq_on=True)),
        ("symphony", base_cfg._replace(sym_on=True)),
    ]
    res = run_grid(topo, wl, [cfg for _, cfg in variants], seeds, "ecmp")
    cct = metrics.cct_seconds(res, wl, base_cfg)[..., 0]   # [K, S]

    out = {}
    for i, (name, _) in enumerate(variants):
        out[name] = {
            "cct_median_s": float(np.nanmedian(cct[i])),
            "cct_p90_s": float(np.nanpercentile(cct[i], 90)),
            "n_unfinished": int(np.isnan(cct[i]).sum()),
        }
    for other in ("baseline", "pq"):
        if out[other]["cct_median_s"]:
            out[f"reduction_vs_{other}"] = round(
                1 - out["symphony"]["cct_median_s"] /
                out[other]["cct_median_s"], 3)
    # record which mesh produced the figure — single- and multi-device
    # dispatches are cached separately (device_fingerprint in the key)
    mesh = resolve_grid_mesh(devices=grid_devices())
    out["grid_device_count"] = 1 if mesh is None else int(mesh.devices.size)
    return out


def bench():
    return cached("fig5_cct_cdf", run, config=CONFIG)
