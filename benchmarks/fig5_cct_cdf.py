"""Paper Fig. 5: CCT distribution for Ring AllReduce — baseline vs strict
priority queueing (PQ) vs Symphony.

Targets: Symphony ~22% lower than baseline and ~19% lower than PQ at the
median; PQ suffers from starvation-induced oscillation.
"""
import numpy as np

from repro.core.netsim import metrics

from .common import QUICK, build_scenario, cached, run_seeds, seeds_for


def run():
    passes = 2 if QUICK else 3
    topo, wl, base_cfg, _ = build_scenario("table1_ring", passes=passes,
                                           horizon_mult=4.5)
    seeds = seeds_for(12, 4)

    out = {}
    for name, cfg in [
        ("baseline", base_cfg),
        ("pq", base_cfg._replace(share_policy="pq")),
        ("symphony", base_cfg._replace(sym_on=True)),
    ]:
        res = run_seeds(topo, wl, cfg, "ecmp", seeds)
        cct = metrics.cct_seconds(res, wl, cfg)[:, 0]
        out[name] = {
            "cct_median_s": float(np.nanmedian(cct)),
            "cct_p90_s": float(np.nanpercentile(cct, 90)),
            "n_unfinished": int(np.isnan(cct).sum()),
        }
    for other in ("baseline", "pq"):
        if out[other]["cct_median_s"]:
            out[f"reduction_vs_{other}"] = round(
                1 - out["symphony"]["cct_median_s"] /
                out[other]["cct_median_s"], 3)
    return out


def bench():
    return cached("fig5_cct_cdf", run)
