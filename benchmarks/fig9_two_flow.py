"""Paper Fig. 9 (hardware prototype scenario): two 1 GB flows through one
switch port, flow A delayed by 250-1000 ms.  Symphony reduces flow A's
completion time (the lagging flow) with a small cost to flow B, and shrinks
the concurrent-transmission window.

We reproduce it as a 2-host netsim scenario: each "flow" is a 1-step ring job
(host0 -> host1) of 1 GB sharing the access-down port.
"""
import numpy as np

from .common import QUICK, cached, run_scenario


def _scenario(delay_a: float, sym: bool):
    # See the "two_flow_fig9" registry entry: hosts 0,1 send to host 2
    # through one ToR egress port; flow B is tagged one step ahead.
    size = 0.25e9 if QUICK else 1e9
    built, res = run_scenario("two_flow_fig9", delay_a=delay_a, size=size,
                              sym=sym)
    ft = np.asarray(res.finish_ticks) * built.cfg.dt
    return float(ft[0] - delay_a), float(ft[1])   # per-flow completion times


def run():
    out = {}
    scale = 0.25 if QUICK else 1.0
    for delay in ([0.125, 0.25] if QUICK else [0.25, 0.5, 1.0]):
        d = delay * scale
        a_b, b_b = _scenario(d, sym=False)
        a_s, b_s = _scenario(d, sym=True)
        out[f"delayA_{delay}s"] = {
            "baseline_A_s": round(a_b, 4), "baseline_B_s": round(b_b, 4),
            "symphony_A_s": round(a_s, 4), "symphony_B_s": round(b_s, 4),
            "A_reduction": round(1 - a_s / a_b, 4),
            "B_cost": round(b_s / b_b - 1, 4),
        }
    return out


def bench():
    return cached("fig9_two_flow", run)
