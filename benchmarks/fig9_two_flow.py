"""Paper Fig. 9 (hardware prototype scenario): two 1 GB flows through one
switch port, flow A delayed by 250-1000 ms.  Symphony reduces flow A's
completion time (the lagging flow) with a small cost to flow B, and shrinks
the concurrent-transmission window.

We reproduce it as a 2-host netsim scenario: each "flow" is a 1-step ring job
(host0 -> host1) of 1 GB sharing the access-down port.
"""
import numpy as np

from repro.core.netsim import (SimParams, WorkloadBuilder, make_leaf_spine,
                               metrics, simulate)

from .common import QUICK, cached


def _scenario(delay_a: float, sym: bool):
    # hosts 0,1 send to host 2: both flows share the ToR egress port
    # (acc_down of host 2), exactly the prototype's single-port contention.
    # Same job, flow B tagged one step ahead (step in the UDP sport, §4.7):
    # B is the outpacing flow, A the lagging one.
    topo = make_leaf_spine(4, 2, 2)
    b = WorkloadBuilder()
    size = 0.25e9 if QUICK else 1e9
    b.add_chain_job(pairs=[(0, 2), (1, 2)], steps=1, chunk_bytes=size,
                    step_offsets=[0, 1], flow_starts=[delay_a, 0.0])
    wl = b.build()
    t_end = 3.2 * (size / 1.25e9) + delay_a + 0.2
    cfg = SimParams(n_ticks=int(t_end / 20e-6), dt=20e-6, window=8,
                    sym_on=sym)
    res = simulate(topo, wl, cfg, routing="balanced", seed=0)
    ft = np.asarray(res.finish_ticks) * cfg.dt
    return float(ft[0] - delay_a), float(ft[1])   # per-flow completion times


def run():
    out = {}
    scale = 0.25 if QUICK else 1.0
    for delay in ([0.125, 0.25] if QUICK else [0.25, 0.5, 1.0]):
        d = delay * scale
        a_b, b_b = _scenario(d, sym=False)
        a_s, b_s = _scenario(d, sym=True)
        out[f"delayA_{delay}s"] = {
            "baseline_A_s": round(a_b, 4), "baseline_B_s": round(b_b, 4),
            "symphony_A_s": round(a_s, 4), "symphony_B_s": round(b_s, 4),
            "A_reduction": round(1 - a_s / a_b, 4),
            "B_cost": round(b_s / b_b - 1, 4),
        }
    return out


def bench():
    return cached("fig9_two_flow", run)
