"""Simulator performance benchmark — the §Perf record for the netsim layer.

Measures, on the Table-1 scenario:

* single-run / multi-seed ticks-per-second (as before);
* **grid dispatch**: a Fig.-8-style 16-point knob grid (tau x k, Symphony
  on) through ``simulate_grid`` — one compile, vmapped — versus *per-point
  dispatch*, where every grid point pays its own trace+compile the way the
  pre-split engine (all of SimParams in ``static_argnames``) did.  Both
  end-to-end wall clock and the compile-only ratio are reported: the
  split converts O(grid) trace+compiles into O(1), so
  ``compile_speedup_vs_per_point`` scales with grid size (>= 5x from ~8
  points up).  End-to-end speedup additionally depends on how well the
  host vectorizes the batched lanes (on a 1-2 core CPU the batched and
  sequential executions run at similar throughput; on parallel backends
  the grid wins on both axes);
* **compile count**: ``core_trace_count()`` across the grid must be
  exactly 1 — the CI smoke job asserts this, so an accidental re-trace in
  the grid executor fails the build.

Under BENCH_QUICK the per-point reference is sampled on a subset of the
grid and extrapolated (compiles dominate it, so this is conservative).

The result also carries an xla-vs-pallas tick-backend comparison and is
persisted as ``BENCH_netsim.json`` at the repo root — the tracked perf
artifact.  ``python -m benchmarks.netsim_perf`` refreshes it;
``python -m benchmarks.netsim_perf --check`` re-measures and compares
against the committed numbers (warn-only: CI hosts are 2-core shared
VMs, so throughput is gated loosely and never fails the build).
"""
import functools
import json
import os
import platform
import sys
import time
from pathlib import Path

import jax

from repro.core.netsim import (core_trace_count, grid_from_params,
                               resolve_grid_mesh, simulate, simulate_grid,
                               simulate_seeds)
from repro.core.netsim.simulator import (_core_impl, _resolve_routing,
                                         build_static, wl_arrays)

from .common import (QUICK, build_scenario, cached, default_params,
                     kernel_tuning, knob_grid)

BENCH_FILE = Path(__file__).resolve().parents[1] / "BENCH_netsim.json"
# Schema 3: adds the append-only "trajectory" list — one entry per PR
# (git sha + kernel configuration + ticks/sec), the longitudinal perf
# record the per-mode snapshot entries cannot provide.
BENCH_SCHEMA = 3

# The gather-free tiled kernel configuration: packed per-block route
# tables streamed via BlockSpec + scalar prefetch remove every gather
# AND scatter from the tiled onehot lowering (the Mosaic-ready shape).
# Benchmarked as its own trajectory variant alongside the tuned window.
GATHERFREE_TUNING = {"segsum": "onehot", "blk": 256, "tick_window": 1}

# single source of truth for the benchmark parameters and the cache key
CONFIG = dict(n_ticks=2_000 if QUICK else 30_000,
              taus=(0.1, 0.2, 0.25, 0.5), ks=(1e-3, 3e-3, 1e-2, 3e-2),
              n_seeds=4 if QUICK else 8,
              grid_seeds=1 if QUICK else 2,
              backends=("xla", "pallas"),
              tuning=kernel_tuning(),
              gatherfree=GATHERFREE_TUNING,
              windows=8 if QUICK else 16)


def _git_sha() -> str:
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=BENCH_FILE.parent, capture_output=True, text=True,
            timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _per_point_reference(topo, wl, cfgs, seed=0):
    """Legacy dispatch: a fresh jit per grid point, as when every SimParams
    field was a static argument — each point re-traces and re-compiles.

    Returns (total_wall_s, total_compile_s): the compile term is measured
    separately via AOT lower+compile of the same fresh program.
    """
    wall = comp = 0.0
    for cfg in cfgs:
        cfg_r, mode = _resolve_routing(cfg, "ecmp")
        st = build_static(topo, wl, mode, seed, dt=cfg_r.dt,
                          deploy=cfg_r.deploy)
        struct, knobs = cfg_r.split()
        wla = wl_arrays(wl, struct.dt)
        key = jax.random.PRNGKey(seed)
        fresh = jax.jit(functools.partial(_core_impl),
                        static_argnames=("struct",))
        t0 = time.time()
        compiled = fresh.lower(st, wla, struct=struct, knobs=knobs,
                               key=key).compile()
        comp += time.time() - t0
        t0 = time.time()
        jax.block_until_ready(compiled(st, wla, knobs=knobs, key=key))
        wall += time.time() - t0
    return wall + comp, comp


def backend_compare(topo, wl, cfg):
    """Warm-run ticks/sec for the staged XLA tick vs the fused Pallas
    kernel (``kernels/netsim_tick``).  On the CPU CI host the kernel runs
    in interpret mode — it traces into the same XLA program, so parity
    (~1.0x) is the expected result there; the fusion win is a memory-
    traffic story on real accelerators (see ``benchmarks/roofline.py``'s
    ``netsim_tick`` section for the analytic bytes-moved model)."""
    from repro.kernels.netsim_tick import use_interpret
    n_ticks = cfg.n_ticks
    tuning = CONFIG["tuning"]
    variants = [("xla", cfg._replace(backend="xla")),
                ("pallas", cfg._replace(backend="pallas")),
                # the trajectory configuration: the fused kernel with the
                # multi-tick window (and any BENCH_SEGSUM/BENCH_BLK
                # overrides) — what BENCH_netsim.json tracks across PRs
                ("pallas_tuned", cfg._replace(backend="pallas", **tuning)),
                # the gather-free Mosaic-ready tiled configuration — the
                # second tracked trajectory variant
                ("pallas_gatherfree",
                 cfg._replace(backend="pallas", **GATHERFREE_TUNING))]
    out = {}
    for be, c in variants:
        t0 = time.time()
        jax.block_until_ready(simulate(topo, wl, c, "ecmp", 0))
        cold = time.time() - t0
        t0 = time.time()
        jax.block_until_ready(simulate(topo, wl, c, "ecmp", 1))
        warm = time.time() - t0
        out[be] = {
            "compile_plus_run_s": round(cold, 2),
            "single_run_s": round(warm, 3),
            "ticks_per_s": round(n_ticks / warm),
        }
    out["pallas_interpret"] = use_interpret()
    out["pallas_vs_xla"] = round(
        out["pallas"]["ticks_per_s"] / out["xla"]["ticks_per_s"], 2)
    out["pallas_tuned_vs_xla"] = round(
        out["pallas_tuned"]["ticks_per_s"] / out["xla"]["ticks_per_s"], 2)
    out["pallas_gatherfree_vs_xla"] = round(
        out["pallas_gatherfree"]["ticks_per_s"] / out["xla"]["ticks_per_s"],
        2)
    return out


def measure_windowed(topo, wl, cfg):
    """``step_overhead``: per-window dispatch cost of the online control
    plane.  The same tick horizon is run once as a closed scan and once as
    ``windows`` sequential ``run_window`` dispatches (the ``step()`` path,
    one host round-trip per window), both warm.  ``step_overhead`` is the
    windowed/one-shot wall ratio — the price of being resumable/retunable
    every window; ``per_window_dispatch_ms`` is the same cost per window.
    """
    from repro.core.netsim import init_state, run_window
    cfg_r, mode = _resolve_routing(cfg, "ecmp")
    struct, knobs = cfg_r.split()
    st = build_static(topo, wl, mode, 0, dt=struct.dt, deploy=struct.deploy)
    wla = wl_arrays(wl, struct.dt)
    R = struct.record_every
    n_win = CONFIG["windows"]
    win = max(R, cfg.n_ticks // n_win // R * R)
    total = win * n_win

    cfg_t = cfg._replace(n_ticks=total)
    jax.block_until_ready(simulate(topo, wl, cfg_t, "ecmp", 0))   # compile
    t0 = time.time()
    jax.block_until_ready(simulate(topo, wl, cfg_t, "ecmp", 1))
    oneshot = time.time() - t0

    key = jax.random.PRNGKey(0)
    state = init_state(st, wla, struct, key)
    jax.block_until_ready(
        run_window(st, wla, struct, knobs, state, win)[0])        # compile
    state = init_state(st, wla, struct, key)
    t0 = time.time()
    for _ in range(n_win):
        state, _ = run_window(st, wla, struct, knobs, state, win)
    jax.block_until_ready(state)
    windowed = time.time() - t0
    return {
        "window_ticks": win,
        "n_windows": n_win,
        "total_ticks": total,
        "oneshot_s": round(oneshot, 3),
        "windowed_s": round(windowed, 3),
        "ticks_per_s": round(total / windowed),
        "step_overhead": round(windowed / oneshot, 3),
        "per_window_dispatch_ms": round(
            max(windowed - oneshot, 0.0) / n_win * 1e3, 3),
    }


def run():
    topo, wl, _, _ = build_scenario("table1_ring", passes=2)
    n_ticks = CONFIG["n_ticks"]
    cfg = default_params(n_ticks, sym=True)

    t0 = time.time()
    jax.block_until_ready(simulate(topo, wl, cfg, "ecmp", 0))
    cold = time.time() - t0
    t0 = time.time()
    jax.block_until_ready(simulate(topo, wl, cfg, "ecmp", 1))
    warm = time.time() - t0

    seeds = list(range(CONFIG["n_seeds"]))
    t0 = time.time()
    jax.block_until_ready(simulate_seeds(topo, wl, cfg, "ecmp", seeds))
    batch = time.time() - t0

    # ---- Fig.-8-style knob grid: 16 points (4 tau x 4 k)
    cfgs = knob_grid(cfg, {"tau": CONFIG["taus"], "k": CONFIG["ks"]})
    struct, knobs = grid_from_params(cfgs)
    grid_seeds = list(range(CONFIG["grid_seeds"]))
    c0 = core_trace_count()
    t0 = time.time()
    jax.block_until_ready(
        simulate_grid(topo, wl, struct, knobs, grid_seeds, routing="ecmp",
                      chunk_knobs=8))
    grid_wall = time.time() - t0
    grid_compiles = core_trace_count() - c0
    # compile-only cost of the grid program, measured the same way as the
    # per-point reference: AOT trace+compile of a fresh jit of the body
    from repro.core.netsim.simulator import (_grid_impl, _stacked_statics)
    struct_r, mode = _resolve_routing(struct, "ecmp")
    st_stack, keys = _stacked_statics(topo, wl, mode, grid_seeds, struct_r)
    kn8 = jax.tree.map(lambda x: x[:8], knobs)
    fresh_grid = jax.jit(functools.partial(_grid_impl),
                         static_argnames=("struct",))
    t0 = time.time()
    fresh_grid.lower(st_stack, wl_arrays(wl, struct_r.dt), struct=struct_r,
                     knobs_stack=kn8, keys=keys).compile()
    grid_compile_s = time.time() - t0

    ref_cfgs = cfgs[:4] if QUICK else cfgs
    pp_total, pp_comp = _per_point_reference(topo, wl, ref_cfgs)
    # honest legacy model: seeds were traced even pre-split, so per-point
    # dispatch pays K compiles but K*S runs
    scale_k = len(cfgs) / len(ref_cfgs)
    pp_run = pp_total - pp_comp
    pp_comp *= scale_k
    pp_wall = pp_comp + pp_run * scale_k * len(grid_seeds)
    backends = backend_compare(topo, wl, cfg)

    # ---- multi-device grid dispatch: the same grid sharded across all
    # local devices (only measurable when >1 device is visible — force a
    # CPU mesh with XLA_FLAGS=--xla_force_host_platform_device_count=8).
    # Both walls include their single compile, so the ratio is honest.
    lanes = len(cfgs) * len(grid_seeds)
    mesh = resolve_grid_mesh(devices="auto")
    n_dev = 1 if mesh is None else int(mesh.devices.size)
    multi = {"grid_devices": n_dev}
    if mesh is not None:
        t0 = time.time()
        jax.block_until_ready(
            simulate_grid(topo, wl, struct, knobs, grid_seeds,
                          routing="ecmp", chunk_knobs=8, devices="auto"))
        multi_wall = time.time() - t0
        multi.update({
            "grid_multi_wall_s": round(multi_wall, 2),
            "grid_speedup_multi_device": round(grid_wall / multi_wall, 2),
            "ticks_per_s_grid_per_device_multi": round(
                lanes * n_ticks / multi_wall / n_dev),
        })
    windowed = measure_windowed(topo, wl, cfg)

    return {
        "backends": backends,
        "windowed": windowed,
        "compile_plus_run_s": round(cold, 2),
        "single_run_s": round(warm, 2),
        "ticks_per_s_single": round(n_ticks / warm),
        "vmap_seeds": len(seeds),
        "vmap_runs_s": round(batch, 2),
        "ticks_per_s_vmap": round(len(seeds) * n_ticks / batch),
        "vmap_speedup": round(len(seeds) * warm / batch, 2),
        "grid_points": len(cfgs),
        "grid_seeds": len(grid_seeds),
        "grid_lanes": lanes,
        "grid_wall_s": round(grid_wall, 2),
        "grid_compiles": grid_compiles,
        # each lane advances n_ticks in grid_wall seconds; "total" is the
        # aggregate simulation throughput of the whole grid dispatch
        "ticks_per_s_grid_lane": round(n_ticks / grid_wall, 1),
        "ticks_per_s_grid_total": round(lanes * n_ticks / grid_wall),
        "ticks_per_s_grid_per_device": round(lanes * n_ticks / grid_wall),
        "per_point_wall_s": round(pp_wall, 2),
        "per_point_compile_s": round(pp_comp, 2),
        "per_point_extrapolated": len(ref_cfgs) != len(cfgs),
        "grid_speedup_vs_per_point": round(pp_wall / grid_wall, 2),
        "compile_speedup_vs_per_point": round(
            pp_comp / max(grid_compile_s, 1e-9), 2),
        **multi,
    }


def bench():
    return cached("netsim_perf", run, config=CONFIG)


# --------------------------------------------- BENCH_netsim.json artifact
def _mode() -> str:
    return "quick" if QUICK else "full"


def write_bench(result) -> dict:
    """Merge this run into the committed perf artifact, keyed by mode
    ("quick" = the CI configuration, "full" = the local 30k-tick one),
    and append this commit's entry to the per-PR ``trajectory`` list."""
    data = {}
    if BENCH_FILE.exists():
        data = json.loads(BENCH_FILE.read_text())
        if data.get("schema") == 2:
            # schema 2 -> 3: mode snapshot entries carry over unchanged;
            # the trajectory starts empty and grows from this run on.
            data["schema"] = BENCH_SCHEMA
        elif data.get("schema") != BENCH_SCHEMA:
            data = {}
    data["schema"] = BENCH_SCHEMA
    mesh = resolve_grid_mesh(devices="auto")
    n_dev = 1 if mesh is None else int(mesh.devices.size)
    data[_mode()] = {
        "config": {k: list(v) if isinstance(v, tuple) else v
                   for k, v in CONFIG.items()},
        # device_count/mesh_shape make BENCH entries from different
        # topologies (1-device CI VM vs forced-8 CPU mesh vs accelerator
        # pods) comparable instead of silently conflated
        "host": {"cpu_count": os.cpu_count(),
                 "machine": platform.machine(),
                 "jax": jax.__version__,
                 "jax_backend": jax.default_backend(),
                 "device_count": jax.device_count(),
                 "mesh_shape": [n_dev]},
        "result": result,
    }
    # ---- append-only per-PR trajectory, one entry per kernel variant
    # (re-running on the same commit, mode, and variant updates that
    # entry in place instead of duplicating it; entries from before the
    # variant field carried the tuned configuration, so missing variant
    # reads as "pallas_tuned")
    sha = _git_sha()
    traj = data.get("trajectory", [])
    for variant, tuning in (("pallas_tuned", CONFIG["tuning"]),
                            ("pallas_gatherfree", GATHERFREE_TUNING),
                            ("windowed", None)):
        if variant == "windowed":
            # the online-control-plane dispatch path: W run_window calls
            # over the closed scan's horizon (xla backend) — tracks the
            # per-window resume/retune cost across PRs.  Absent from
            # partial results (e.g. the dedupe test's fixture): skip.
            w = result.get("windowed")
            if w is None:
                continue
            entry = {
                "sha": sha,
                "mode": _mode(),
                "variant": variant,
                "backend": "xla",
                "segsum": None, "blk": None, "tick_window": None,
                "window_ticks": w["window_ticks"],
                "n_windows": w["n_windows"],
                "ticks_per_s": w["ticks_per_s"],
                "step_overhead": w["step_overhead"],
                "ticks_per_s_xla": result["backends"]["xla"]["ticks_per_s"],
                "device_count": jax.device_count(),
            }
        else:
            entry = {
                "sha": sha,
                "mode": _mode(),
                "variant": variant,
                "backend": "pallas",
                "segsum": tuning["segsum"],
                "blk": tuning["blk"],
                "tick_window": tuning["tick_window"],
                "lanes": result.get("grid_lanes"),
                "ticks_per_s": result["backends"][variant]["ticks_per_s"],
                "ticks_per_s_xla": result["backends"]["xla"]["ticks_per_s"],
                "device_count": jax.device_count(),
            }
        traj = [e for e in traj
                if not (e.get("sha") == entry["sha"]
                        and e.get("mode") == entry["mode"]
                        and e.get("variant", "pallas_tuned")
                        == entry["variant"])]
        traj.append(entry)
    data["trajectory"] = traj
    BENCH_FILE.write_text(json.dumps(data, indent=1, sort_keys=True) + "\n")
    return data


# Ticks/sec metrics gated by --check, as (path into the result dict).
# grid_speedup_multi_device only exists when >1 device was visible for
# BOTH the committed and the fresh run; the check loop skips it otherwise.
_GATED = (("ticks_per_s_single",), ("ticks_per_s_vmap",),
          ("backends", "xla", "ticks_per_s"),
          ("backends", "pallas", "ticks_per_s"),
          ("backends", "pallas_tuned", "ticks_per_s"),
          ("backends", "pallas_gatherfree", "ticks_per_s"),
          ("windowed", "ticks_per_s"),
          ("grid_speedup_multi_device",))
# Warn below 0.5x committed: CI runs on shared 2-core VMs whose absolute
# throughput swings widely run-to-run, so the gate is loose and warn-only —
# it catches order-of-magnitude regressions, not percent-level ones.
CHECK_RATIO = 0.5


def check() -> int:
    """Warn-only regression gate against the committed BENCH_netsim.json."""
    if not BENCH_FILE.exists():
        print(f"netsim_perf --check: no {BENCH_FILE.name}; skipping")
        return 0
    data = json.loads(BENCH_FILE.read_text())
    entry = data.get(_mode())
    if data.get("schema") != BENCH_SCHEMA or entry is None:
        print(f"netsim_perf --check: no committed '{_mode()}' entry "
              f"(schema {data.get('schema')}); skipping")
        return 0
    committed, fresh = entry["result"], run()
    warned = False
    for path in _GATED:
        want, have = committed, fresh
        try:
            for k in path:
                want, have = want[k], have[k]
        except KeyError:
            continue
        if not all(isinstance(v, (int, float)) for v in (want, have)) \
                or want <= 0:
            continue
        label = ".".join(path)
        line = (f"  {label}: {have} vs committed {want} "
                f"({have / want:.2f}x)")
        if have < CHECK_RATIO * want:
            # ::warning:: renders as a GitHub Actions annotation
            print(f"::warning title=netsim_perf regression::{label} "
                  f"{have} < {CHECK_RATIO} * committed {want}")
            warned = True
        print(line)
    # ---- trajectory gate: fresh fused-kernel throughput vs the newest
    # committed trajectory entry for this mode AND variant (same
    # warn-only contract; pre-variant entries read as pallas_tuned)
    for variant in ("pallas_tuned", "pallas_gatherfree", "windowed"):
        traj = [e for e in data.get("trajectory", [])
                if e.get("mode") == _mode()
                and e.get("variant", "pallas_tuned") == variant
                and isinstance(e.get("ticks_per_s"), (int, float))]
        if not traj:
            print(f"  trajectory[{variant}]: no committed entry for mode "
                  f"'{_mode()}' yet")
            continue
        last = traj[-1]
        want = last["ticks_per_s"]
        have = (fresh["windowed"]["ticks_per_s"] if variant == "windowed"
                else fresh["backends"][variant]["ticks_per_s"])
        print(f"  trajectory[{last.get('sha')}/{variant}].ticks_per_s: "
              f"{have} vs committed {want} ({have / want:.2f}x; segsum="
              f"{last.get('segsum')} blk={last.get('blk')} "
              f"tick_window={last.get('tick_window')})")
        if want > 0 and have < CHECK_RATIO * want:
            print(f"::warning title=netsim_perf trajectory regression::"
                  f"{variant} {have} < {CHECK_RATIO} * committed {want} "
                  f"(entry {last.get('sha')})")
            warned = True
    host = entry.get("host", {})
    print(f"  committed on {host.get('cpu_count')}-core "
          f"{host.get('machine')} / jax {host.get('jax')}; warn-only "
          f"(shared 2-core CI hosts make hard throughput gates meaningless)")
    print("netsim_perf --check:", "WARNINGS above" if warned else "ok")
    return 0


def main(argv) -> int:
    if "--check" in argv:
        return check()
    res = bench()
    write_bench(res)
    print(json.dumps(res, indent=1))
    print(f"wrote {BENCH_FILE}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
