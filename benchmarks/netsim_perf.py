"""Simulator performance benchmark: ticks/second for the Table-1 scenario
(single run and vmapped over seeds) — the §Perf record for the netsim layer."""
import time

import jax

from repro.core.netsim import simulate, simulate_seeds

from .common import build_scenario, cached, default_params


def run():
    topo, wl, _, _ = build_scenario("table1_ring", passes=2)
    n_ticks = 30_000
    cfg = default_params(n_ticks, sym=True)

    t0 = time.time()
    jax.block_until_ready(simulate(topo, wl, cfg, "ecmp", 0))
    cold = time.time() - t0
    t0 = time.time()
    jax.block_until_ready(simulate(topo, wl, cfg, "ecmp", 1))
    warm = time.time() - t0

    seeds = list(range(8))
    t0 = time.time()
    jax.block_until_ready(simulate_seeds(topo, wl, cfg, "ecmp", seeds))
    batch = time.time() - t0
    return {
        "compile_plus_run_s": round(cold, 2),
        "single_run_s": round(warm, 2),
        "ticks_per_s_single": round(n_ticks / warm),
        "vmap8_runs_s": round(batch, 2),
        "ticks_per_s_vmap8": round(8 * n_ticks / batch),
        "vmap_speedup": round(8 * warm / batch, 2),
    }


def bench():
    return cached("netsim_perf", run)
