"""End-to-end system behaviour tests.

The heavier pieces (multi-device dry-run lowering, ring-grad-sync training)
run in subprocesses because jax locks the host device count at first init.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(script: str, timeout=900, devices: int | None = None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


def test_dryrun_cell_lowers_and_compiles_on_production_mesh():
    """One full-size cell through the real dry-run path at 512 devices."""
    out = _run(r"""
from repro.launch.dryrun import run_cell
res = run_cell("mamba2_130m", "decode_32k", multi_pod=True)
assert res["ok"]
assert res["chips"] == 512
assert res["t_compute"] >= 0 and res["t_memory"] > 0
print("MULTIPOD_OK", res["memory"]["per_device_total"])
""")
    assert "MULTIPOD_OK" in out


def test_ring_grad_sync_training_runs_multidevice():
    """4-device manual-DP training with explicit ring gradient sync."""
    out = _run(r"""
import jax, numpy as np
from repro.config import ModelConfig, ParallelConfig, TrainConfig
from repro.models import build_model
from repro.runtime.train import make_train_step
from repro.optim.adamw import init_opt_state
from repro.launch.mesh import make_mesh
import jax.numpy as jnp

cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  attention="gqa")
mesh = make_mesh((4,), ("data",))
par = ParallelConfig(grad_sync="ring", scan_layers=False, remat="none")
model = build_model(cfg, par, mesh=mesh)
tcfg = TrainConfig(global_batch=8, seq_len=32, lr=1e-2, warmup_steps=2,
                   total_steps=20)
params = model.init(jax.random.PRNGKey(0))
opt = init_opt_state(params, tcfg)
step = make_train_step(model, cfg, tcfg, par, mesh)
rng = np.random.default_rng(0)
losses = []
for s in range(12):
    toks = rng.integers(0, 256, (8, 32)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.asarray(np.roll(toks, -1, 1))}
    params, opt, m = step(params, opt, batch)
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print("RING_TRAIN_OK", losses[0], losses[-1])
""", devices=4)
    assert "RING_TRAIN_OK" in out


def test_xla_vs_ring_grad_sync_agree():
    """Both grad-sync paths produce (nearly) identical updates."""
    out = _run(r"""
import jax, numpy as np, jax.numpy as jnp
from repro.config import ModelConfig, ParallelConfig, TrainConfig
from repro.models import build_model
from repro.runtime.train import make_train_step
from repro.optim.adamw import init_opt_state
from repro.launch.mesh import make_mesh

cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  attention="gqa")
mesh = make_mesh((4,), ("data",))
tcfg = TrainConfig(global_batch=8, seq_len=32, lr=1e-2, warmup_steps=2,
                   total_steps=20)
rng = np.random.default_rng(0)
toks = rng.integers(0, 256, (8, 32)).astype(np.int32)
batch = {"tokens": jnp.asarray(toks),
         "labels": jnp.asarray(np.roll(toks, -1, 1))}
outs = {}
for sync in ["xla", "ring"]:
    par = ParallelConfig(grad_sync=sync, scan_layers=False, remat="none")
    model = build_model(cfg, par, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params, tcfg)
    step = make_train_step(model, cfg, tcfg, par, mesh)
    p2, _, m = step(params, opt, batch)
    outs[sync] = (jax.tree.leaves(p2), float(m["loss"]))
for a, b in zip(*[outs[s][0] for s in ["xla", "ring"]]):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-2,
                               rtol=2e-2)
assert abs(outs["xla"][1] - outs["ring"][1]) < 1e-2
print("SYNC_AGREE_OK")
""", devices=4)
    assert "SYNC_AGREE_OK" in out


def test_tp_sharded_training_hlo_has_collectives():
    """TP + SP train step lowers with the expected collective structure."""
    out = _run(r"""
import jax
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_cell
mesh = make_mesh((2, 4), ("data", "model"))
cell = build_cell("h2o_danube_3_4b", "train_4k", mesh)
with mesh:
    txt = jax.jit(cell.fn, donate_argnums=cell.donate).lower(
        *cell.args).compile().as_text()
# TP matmuls + DP grad sync must lower to collectives
import re
kinds = set(re.findall(r"(all-reduce|all-gather|reduce-scatter|all-to-all)",
                       txt))
assert len(kinds) >= 2, kinds
print("SP_HLO_OK", sorted(kinds))
""", devices=8, timeout=1200)
    assert "SP_HLO_OK" in out
