"""MoE dispatch correctness: EP path vs a dense (all-experts) reference,
plus multi-device equality (subprocess)."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig
from repro.models.moe import moe_block, moe_spec
from repro.models.params import init_tree

ROOT = Path(__file__).resolve().parents[1]


def _cfg(E=8, k=2, cf=8.0):
    return ModelConfig(
        name="moe-test", family="moe", num_layers=1, d_model=32,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=E, experts_per_token=k, d_ff_expert=16,
                      capacity_factor=cf))


def _dense_reference(p, x, cfg):
    """Compute through all experts densely, combine with top-k gates."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, m.experts_per_token)
    gates = gates / gates.sum(-1, keepdims=True)
    h = jnp.einsum("td,edif->teif", xt, p["wi"])
    g, u = h[..., 0, :], h[..., 1, :]
    a = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("tef,efd->ted", a, p["wo"])        # [T, E, d]
    sel = jnp.take_along_axis(ye, idx[..., None], axis=1)
    y = (sel * gates[..., None].astype(x.dtype)).sum(1)
    return y.reshape(B, S, d)


def test_ep_matches_dense_single_device():
    cfg = _cfg(cf=8.0)   # capacity high enough that nothing drops
    spec = moe_spec(cfg)
    params = init_tree(jax.random.PRNGKey(0), spec)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y, aux = moe_block(params, x, cfg)
    y_ref = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-5)
    assert float(aux) > 0


def test_capacity_drop_is_bounded():
    """With tight capacity some tokens drop, but output stays finite and
    close in norm."""
    cfg = _cfg(cf=1.0)
    spec = moe_spec(cfg)
    params = init_tree(jax.random.PRNGKey(0), spec)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y, _ = moe_block(params, x, cfg)
    assert bool(jnp.isfinite(y).all())


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.config import ModelConfig, MoEConfig
from repro.models.moe import moe_block, moe_spec
from repro.models.params import init_tree
from repro.parallel.sharding import make_rules

cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                  num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                  moe=MoEConfig(num_experts=8, experts_per_token=2,
                                d_ff_expert=16, capacity_factor=8.0))
spec = moe_spec(cfg)
params = init_tree(jax.random.PRNGKey(0), spec)
params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

y1, _ = moe_block(params, x, cfg)                       # 1-device path

mesh = make_mesh((2, 4), ("data", "model"))
rules = make_rules()
y8, _ = jax.jit(lambda p, v: moe_block(p, v, cfg, rules, mesh))(params, x)
np.testing.assert_allclose(np.asarray(y1), np.asarray(y8), atol=1e-4,
                           rtol=1e-4)
print("ALLPASS")
"""


def test_ep_multidevice_matches_single():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALLPASS" in r.stdout
