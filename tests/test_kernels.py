"""Pallas kernel validation (interpret mode): shape/dtype sweeps vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.switch_pipeline.kernel import switch_pipeline
from repro.kernels.switch_pipeline.ref import pipeline_ref
from repro.core.symphony import SymphonyParams
from repro.models.ssm import ssd_reference


# ----------------------------------------------------------- flash attention

FLASH_CASES = [
    # (BH, Hkv_groups, S, D, window, dtype)
    (4, 2, 256, 64, 0, jnp.float32),
    (2, 1, 512, 128, 0, jnp.float32),
    (4, 4, 256, 64, 128, jnp.float32),     # sliding window
    (2, 2, 384, 64, 0, jnp.bfloat16),      # S not multiple of 256
    (8, 1, 256, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("BH,groups,S,D,window,dtype", FLASH_CASES)
def test_flash_fwd_matches_ref(BH, groups, S, D, window, dtype):
    bq = bk = 128
    if S % bq:
        pytest.skip("kernel requires 128-aligned seq")
    key = jax.random.PRNGKey(0)
    BHkv = BH // groups
    q = jax.random.normal(key, (BH, S, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (BHkv, S, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (BHkv, S, D), dtype)
    o, lse = flash_fwd(q, k, v, scale=1 / np.sqrt(D), window=window)
    o_ref, lse_ref = attention_ref(q, k, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               atol=1e-2, rtol=1e-2)


def test_flash_grads_match_ref():
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, D = 2, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, Hq, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D))

    def loss_k(q, k, v):
        return (flash_attention(q, k, v) ** 2).sum()

    def loss_r(q, k, v):
        qf = q.transpose(0, 2, 1, 3).reshape(-1, S, D)
        kf = k.transpose(0, 2, 1, 3).reshape(-1, S, D)
        vf = v.transpose(0, 2, 1, 3).reshape(-1, S, D)
        o, _ = attention_ref(qf, kf, vf)
        return (o ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


# ----------------------------------------------------------------- SSD

SSD_CASES = [
    (2, 256, 3, 32, 16, 64, jnp.float32),
    (1, 128, 2, 64, 32, 32, jnp.float32),
    (2, 200, 2, 32, 16, 64, jnp.float32),   # ragged: pads internally
    (2, 256, 4, 64, 16, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,Pdim,N,chunk,dtype", SSD_CASES)
def test_ssd_matches_ref(B, S, H, Pdim, N, chunk, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, S, H, Pdim), dtype).astype(jnp.float32)
    a = -jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (B, S, H))) * 0.1
    Bm = jax.random.normal(jax.random.PRNGKey(2), (B, S, N))
    Cm = jax.random.normal(jax.random.PRNGKey(3), (B, S, N))
    y, fs = ssd(x, a, Bm, Cm, chunk=chunk)
    pad = (-S) % chunk
    if pad:
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ap = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bp = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cp = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y_ref, fs_ref = ssd_reference(xp, ap, Bp, Cp, chunk=chunk)
        y_ref = y_ref[:, :S]
    else:
        y_ref, fs_ref = ssd_reference(x, a, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fs_ref),
                               atol=5e-4, rtol=5e-4)


def test_ssd_equals_sequential_recurrence():
    """Chunked SSD == naive per-token state recurrence."""
    B, S, H, Pdim, N = 1, 64, 2, 8, 4
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, S, H, Pdim))
    a = -jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (B, S, H))) * 0.2
    Bm = jax.random.normal(jax.random.PRNGKey(2), (B, S, N))
    Cm = jax.random.normal(jax.random.PRNGKey(3), (B, S, N))
    y, fs = ssd(x, a, Bm, Cm, chunk=16)
    state = np.zeros((B, H, Pdim, N))
    ys = np.zeros((B, S, H, Pdim))
    xn, an, Bn, Cn = map(np.asarray, (x, a, Bm, Cm))
    for t in range(S):
        state = state * np.exp(an[:, t])[:, :, None, None] + \
            np.einsum("bhp,bn->bhpn", xn[:, t], Bn[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, Cn[:, t])
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), state, atol=1e-4, rtol=1e-4)


# ------------------------------------------------------- switch pipeline

def test_switch_pipeline_bit_exact():
    rng = np.random.default_rng(42)
    n = 3000
    steps = np.maximum(0, rng.integers(0, 6, n) + np.arange(n) // 300)
    psns = rng.integers(1, 5000, n)
    lasts = rng.random(n) < 0.02
    wins = np.arange(n) % 100 == 99
    us = rng.random(n)
    args = [jnp.asarray(a) for a in
            (steps.astype(np.int32), psns.astype(np.float32),
             lasts.astype(np.int32), wins.astype(np.int32),
             us.astype(np.float32))]
    mk, sm, pr, al = switch_pipeline(*args, exact=True)
    mr, sr, prr, ar = pipeline_ref(*args, SymphonyParams())
    assert bool((mk == mr).all())
    assert bool((sm == sr).all())
    np.testing.assert_allclose(np.asarray(pr), np.asarray(prr))
    np.testing.assert_allclose(np.asarray(al), np.asarray(ar))


def test_switch_pipeline_lut_close():
    """The ASIC log/LUT marking path approximates the exact mark rate."""
    rng = np.random.default_rng(7)
    n = 8000
    steps = np.maximum(0, rng.integers(0, 4, n) + np.arange(n) // 200)
    psns = rng.integers(1, 5000, n)
    lasts = rng.random(n) < 0.02
    wins = np.arange(n) % 100 == 99
    us = rng.random(n)
    args = [jnp.asarray(a) for a in
            (steps.astype(np.int32), psns.astype(np.float32),
             lasts.astype(np.int32), wins.astype(np.int32),
             us.astype(np.float32))]
    mk_e, sm_e, *_ = switch_pipeline(*args, exact=True)
    mk_l, sm_l, *_ = switch_pipeline(*args, exact=False)
    # state trajectory is exact regardless of the marking approximation
    assert bool((sm_e == sm_l).all())
    re, rl = float(mk_e.mean()), float(mk_l.mean())
    assert abs(re - rl) < 0.02 + 0.25 * re
