"""Unit + property tests for the Symphony state machine (paper Alg. 1).

The property tests need ``hypothesis`` (optional dev dependency, see
pyproject.toml); without it the whole module is skipped at collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core.symphony import (Packet, SymphonyParams, SymphonyState,
                                 init_state, marking_probability,
                                 process_packet, process_packet_batch,
                                 window_update)

P = SymphonyParams()


def _pkt(step, psn, last=False):
    return Packet(jnp.int32(step), jnp.float32(psn), jnp.asarray(last))


def test_last_bit_advances_step_min():
    st0 = init_state()
    st1, _ = process_packet(st0, _pkt(3, 100, last=True), P, jnp.float32(1.0))
    assert int(st1.step_min) == 4
    assert float(st1.psn_rec) == 0.0


def test_lazy_correction_on_lagging_packet():
    st0 = init_state()._replace(step_min=jnp.int32(5))
    st1, _ = process_packet(st0, _pkt(2, 77), P, jnp.float32(1.0))
    assert int(st1.step_min) == 2
    assert float(st1.psn_rec) == 77.0


def test_aligned_packet_tracks_max_psn():
    st0 = init_state()._replace(step_min=jnp.int32(2),
                                psn_rec=jnp.float32(50.0))
    st1, _ = process_packet(st0, _pkt(2, 80), P, jnp.float32(1.0))
    assert float(st1.psn_rec) == 80.0
    st2, _ = process_packet(st1, _pkt(2, 10), P, jnp.float32(1.0))
    assert float(st2.psn_rec) == 80.0  # max, not last


def test_duplicate_packets_idempotent_state():
    """Retransmissions must not corrupt state (paper §3.4.1)."""
    st0 = init_state()._replace(step_min=jnp.int32(3),
                                psn_rec=jnp.float32(40.0))
    st1, _ = process_packet(st0, _pkt(3, 40), P, jnp.float32(1.0))
    st2, _ = process_packet(st1, _pkt(3, 40), P, jnp.float32(1.0))
    assert int(st1.step_min) == int(st2.step_min)
    assert float(st1.psn_rec) == float(st2.psn_rec)


def test_lagging_never_marked():
    st0 = init_state()._replace(step_min=jnp.int32(5),
                                psn_rec=jnp.float32(1000.0),
                                alpha=jnp.float32(64.0))
    for step in [0, 3, 5]:
        p = marking_probability(jnp.int32(step), jnp.float32(1e9),
                                st0.step_min, st0.psn_rec, st0.alpha, P)
        assert float(p) == 0.0


def test_warmup_guard_suppresses_marking():
    p = marking_probability(jnp.int32(9), jnp.float32(1e9), jnp.int32(1),
                            jnp.float32(float(P.n_warmup)), jnp.float32(64.0), P)
    assert float(p) == 0.0


def test_window_update_eq5():
    # rho >= tau -> alpha += 1
    st0 = init_state()._replace(cnt_total=jnp.float32(100.0),
                                cnt_op=jnp.float32(30.0))
    st1 = window_update(st0, P)
    assert float(st1.alpha) == 2.0
    assert float(st1.cnt_total) == 0.0 and float(st1.cnt_op) == 0.0
    assert float(st1.psn_rec) == 0.0     # time-windowed max reset
    # rho < tau -> alpha decays, floor 1
    st2 = init_state()._replace(cnt_total=jnp.float32(100.0),
                                cnt_op=jnp.float32(10.0))
    assert float(window_update(st2, P).alpha) == 1.0


def test_sample_guard():
    st0 = init_state()._replace(cnt_total=jnp.float32(5.0),
                                cnt_op=jnp.float32(5.0))
    assert float(window_update(st0, P).alpha) == 1.0  # skipped (too few)


@settings(max_examples=200, deadline=None)
@given(
    steps=st.lists(st.integers(0, 30), min_size=1, max_size=60),
    psns=st.lists(st.integers(0, 10000), min_size=60, max_size=60),
    lasts=st.lists(st.booleans(), min_size=60, max_size=60),
    us=st.lists(st.floats(0, 1, exclude_max=True), min_size=60, max_size=60),
)
def test_property_invariants(steps, psns, lasts, us):
    n = len(steps)
    state = init_state()
    for i in range(n):
        prev = state
        state, mark = process_packet(
            state, _pkt(steps[i], psns[i], lasts[i]), P,
            jnp.float32(us[i]))
        # alpha only changes at window boundaries
        assert float(state.alpha) == float(prev.alpha)
        # counters are monotone within a window
        assert float(state.cnt_total) == float(prev.cnt_total) + 1
        assert float(state.cnt_op) >= float(prev.cnt_op)
        # step_min bounded by the packets seen
        assert int(state.step_min) <= max(s + 1 for s in steps[:i + 1])
        # lagging/aligned packets are never marked
        if steps[i] <= int(prev.step_min):
            assert not bool(mark)
        if i % 10 == 9:
            state = window_update(state, P)
            assert 1.0 <= float(state.alpha) <= float(P.alpha_max)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_scan_matches_loop(seed):
    """process_packet_batch (lax.scan) == the python loop."""
    rng = np.random.default_rng(seed)
    n = 40
    steps = rng.integers(0, 8, n).astype(np.int32)
    psns = rng.integers(0, 1000, n).astype(np.float32)
    lasts = rng.random(n) < 0.1
    us = rng.random(n).astype(np.float32)
    state = init_state()
    marks_loop = []
    for i in range(n):
        state, m = process_packet(state, _pkt(steps[i], psns[i], lasts[i]),
                                  P, jnp.float32(us[i]))
        marks_loop.append(bool(m))
    state2, marks = process_packet_batch(
        init_state(), jnp.asarray(steps), jnp.asarray(psns),
        jnp.asarray(lasts), jnp.asarray(us), P)
    assert marks_loop == [bool(x) for x in marks]
    assert int(state.step_min) == int(state2.step_min)
    np.testing.assert_allclose(float(state.psn_rec), float(state2.psn_rec))
