"""Isolated oracles for the bandwidth-share stage functions.

The share policies are otherwise only exercised through full-engine runs;
here each is checked against a straightforward NumPy loop oracle on small
hand-built link tables (explicit routes, capacities, weights), plus
behavioral properties (weight splits, deficit redistribution).
"""
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.netsim.params import SimParams
from repro.core.netsim.stages import (InstView, share_drr, share_proportional,
                                      share_wfq)


def _mini(routes, active, rate, cap, job=None, weight=None):
    """Hand-built (ctx, inst): N instances with explicit [N, H] routes over
    L real links + the trailing null link (id L, infinite cap)."""
    routes = np.asarray(routes, np.int32)
    n, h = routes.shape
    L = len(cap)
    cap_full = np.append(np.asarray(cap, np.float32), 1e30)
    job = np.zeros(n, np.int32) if job is None else np.asarray(job, np.int32)
    weight = np.ones(int(job.max()) + 1, np.float32) if weight is None \
        else np.asarray(weight, np.float32)
    st = SimpleNamespace(
        cap=jnp.asarray(cap_full),
        job_weight=jnp.asarray(weight),
        bg_base=jnp.zeros(L + 1, jnp.float32),
        bg_amp=jnp.zeros(L + 1, jnp.float32),
        bg_period_ticks=jnp.int32(100),
        bg_duty=jnp.float32(0.0))
    ctx = SimpleNamespace(st=st, L=L, J=int(job.max()) + 1,
                          inst_job=jnp.asarray(job))
    z_i = jnp.zeros(n, jnp.int32)
    z_f = jnp.zeros(n, jnp.float32)
    inst = InstView(
        istep=z_i, isent=z_f, irate=jnp.asarray(rate, jnp.float32),
        iseg=z_i, ichunk=z_f, iwire=jnp.arange(n, dtype=jnp.int32),
        ipsn=z_f, occupied=jnp.asarray(active), retired=jnp.zeros(n, bool),
        complete=jnp.zeros(n, bool), active=jnp.asarray(active),
        iroute=jnp.asarray(routes), flat_links=jnp.asarray(routes.reshape(-1)),
        idom=jnp.zeros((n, h), jnp.int32), dj=jnp.zeros((n, h), jnp.int32),
        djf=jnp.zeros(n * h, jnp.int32))
    return ctx, inst, cap_full, routes, job, weight


def _np_offered(routes, w_rate, cap_full):
    offered = np.zeros_like(cap_full)
    for i, r in enumerate(routes):
        for l in r:
            offered[l] += w_rate[i]
    return offered


def test_proportional_matches_numpy_oracle():
    # two insts share link 0 (cap 10); inst 2 alone on link 1 (cap 4)
    ctx, inst, cap_full, routes, _, _ = _mini(
        routes=[[0, 2], [0, 2], [1, 2]],
        active=[True, True, True],
        rate=[8.0, 8.0, 8.0], cap=[10.0, 4.0, 100.0])
    shr = share_proportional(ctx, SimParams(), inst, 0)
    w = np.array([8.0, 8.0, 8.0], np.float32)
    offered = _np_offered(routes, w, cap_full)
    s_l = np.minimum(1.0, cap_full / np.maximum(offered, 1.0))
    eff = w * np.array([s_l[r].min() for r in routes])
    np.testing.assert_allclose(np.asarray(shr.eff), eff, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(shr.offered), offered, rtol=1e-6)
    # link 0 oversubscribed 16/10 -> each gets 5; link 1 at 8/4 -> gets 4
    np.testing.assert_allclose(np.asarray(shr.eff), [5.0, 5.0, 4.0],
                               rtol=1e-6)


def test_proportional_inactive_and_null_link():
    ctx, inst, cap_full, routes, _, _ = _mini(
        routes=[[0, 1], [0, 1]], active=[True, False],
        rate=[50.0, 50.0], cap=[10.0, 10.0])
    shr = share_proportional(ctx, SimParams(), inst, 0)
    eff = np.asarray(shr.eff)
    assert eff[1] == 0.0                      # inactive contributes nothing
    np.testing.assert_allclose(eff[0], 10.0, rtol=1e-6)  # capped by link
    assert np.asarray(shr.offered)[-1] == 0.0  # null link row untouched


def test_wfq_matches_numpy_oracle_and_weight_split():
    # two jobs through the same link, weights 3:1, both rate-unlimited
    ctx, inst, cap_full, routes, job, weight = _mini(
        routes=[[0], [0]], active=[True, True], rate=[100.0, 100.0],
        cap=[8.0], job=[0, 1], weight=[3.0, 1.0])
    shr = share_wfq(ctx, SimParams(share_policy="wfq"), inst, 0)
    w_rate = np.array([100.0, 100.0], np.float32)
    wgt = weight[job]
    wsum = _np_offered(routes, wgt, cap_full)
    fair = np.maximum(cap_full - 0.0, 0.0) / np.maximum(wsum, 1e-9)
    allowed = np.array([wgt[i] * fair[r].min() for i, r in enumerate(routes)])
    eff = np.minimum(w_rate, allowed)
    np.testing.assert_allclose(np.asarray(shr.eff), eff, rtol=1e-6)
    # weight 3 job gets 3x the bandwidth: 6 vs 2 of the 8-unit link
    np.testing.assert_allclose(np.asarray(shr.eff), [6.0, 2.0], rtol=1e-6)
    # offered reports demand, not allocation
    np.testing.assert_allclose(np.asarray(shr.offered)[0], 200.0, rtol=1e-6)


def test_drr_matches_numpy_oracle_with_redistribution():
    # three insts on one 12-unit link; inst 0 wants only 2, so its unused
    # 2 units of the equal 4-unit quantum are redistributed to the others
    ctx, inst, cap_full, routes, _, _ = _mini(
        routes=[[0], [0], [0]], active=[True, True, True],
        rate=[2.0, 100.0, 100.0], cap=[12.0])
    shr = share_drr(ctx, SimParams(share_policy="drr"), inst, 0)
    w_rate = np.array([2.0, 100.0, 100.0], np.float32)
    act = np.array([1.0, 1.0, 1.0], np.float32)
    n_act = _np_offered(routes, act, cap_full)
    avail = np.maximum(cap_full - 0.0, 0.0)
    quantum = avail / np.maximum(n_act, 1.0)
    take1 = np.minimum(w_rate, np.array([quantum[r].min() for r in routes]))
    used = _np_offered(routes, take1, cap_full)
    want = take1 < w_rate
    n_want = _np_offered(routes, want.astype(np.float32), cap_full)
    bonus = np.maximum(avail - used, 0.0) / np.maximum(n_want, 1.0)
    take2 = np.where(
        want, np.minimum(w_rate - take1,
                         np.array([bonus[r].min() for r in routes])), 0.0)
    np.testing.assert_allclose(np.asarray(shr.eff), take1 + take2, rtol=1e-6)
    # 2 + 5 + 5 = 12: the short flow's slack reaches the hungry ones
    np.testing.assert_allclose(np.asarray(shr.eff), [2.0, 5.0, 5.0],
                               rtol=1e-6)


def test_drr_multi_hop_bottleneck():
    # inst 0 crosses links 0 and 1; link 1 (cap 3, shared with inst 1)
    # is the bottleneck, so inst 0's quantum is min over both hops
    ctx, inst, cap_full, routes, _, _ = _mini(
        routes=[[0, 1], [1, 1]], active=[True, True],
        rate=[100.0, 100.0], cap=[20.0, 3.0])
    shr = share_drr(ctx, SimParams(share_policy="drr"), inst, 0)
    eff = np.asarray(shr.eff)
    assert eff[0] <= 3.0 + 1e-5
    # delivered load on the bottleneck stays within capacity
    assert eff[0] + 2 * eff[1] <= 2 * 3.0 + 1e-4


def test_share_helpers_consistency():
    """InstView.link_sum / path_min agree with a NumPy scatter/gather."""
    ctx, inst, cap_full, routes, _, _ = _mini(
        routes=[[0, 1], [1, 2], [2, 0]], active=[True, True, True],
        rate=[1.0, 2.0, 3.0], cap=[5.0, 5.0, 5.0])
    vals = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    got = np.asarray(inst.link_sum(ctx, vals))
    np.testing.assert_allclose(got, _np_offered(routes, np.asarray(vals),
                                                cap_full))
    per_link = jnp.arange(4, dtype=jnp.float32)
    got_min = np.asarray(inst.path_min(per_link))
    want_min = np.array([min(r) for r in routes], np.float32)
    np.testing.assert_allclose(got_min, want_min)
