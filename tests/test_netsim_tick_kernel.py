"""Equivalence tests for the fused ``kernels/netsim_tick`` Pallas kernel.

The staged XLA engine is the golden reference: in interpret mode with
``segsum="scatter"`` the kernel must match it **bit-for-bit**, both
per-output on single ticks and tick-for-tick through whole runs — the
seed golden chain (Table-1 finish-tick constants) must hold unchanged
under ``backend="pallas"``.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.netsim import (SimParams, WorkloadBuilder, build_static,
                               make_leaf_spine, simulate, simulate_grid)
from repro.core.netsim.simulator import wl_arrays
from repro.core.netsim.stages import (engine_tick, engine_tick_xla,
                                      init_state, make_ctx, resolve_backend,
                                      stage_starts)
from repro.kernels.netsim_tick import (fused_outputs_ref, fused_tick,
                                       engine_tick_fused)

# Same constants as tests/test_netsim_engine.py: captured from the seed
# engine on the Table-1 scenario.  The pallas backend must reproduce them.
GOLDEN_JOB = {"ecmp_base": 10757, "ecmp_sym": 7900,
              "balanced_sym": 2239, "ecmp_pq": 10303}


def _table1():
    topo = make_leaf_spine(32, 4, 4)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(32)), ring_size=8, chunk_bytes=1e6,
                   passes=2, barrier=False)
    return topo, b.build()


def _small():
    topo = make_leaf_spine(8, 2, 2)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(8)), ring_size=4, chunk_bytes=2e5,
                   passes=1, barrier=False)
    return topo, b.build()


def _assert_results_equal(a, b, what):
    for f in a._fields:
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f"{what}: {f}"


# ------------------------------------------------- single-tick, per-output
@pytest.mark.parametrize("variant", [
    dict(), dict(sym_on=True), dict(pq_on=True), dict(share_policy="pq")])
def test_kernel_outputs_bitwise_vs_stage_oracle(variant):
    """Every kernel output equals the stage-function oracle, bitwise, on a
    nontrivial mid-run state — including a sym-window epoch tick."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, **variant)
    st = build_static(topo, wl, "ecmp", seed=3, dt=cfg.dt, deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    state = init_state(ctx, jax.random.PRNGKey(0))
    # Both sides jitted: the kernel body always compiles as one XLA
    # computation, and an eager (op-by-op) oracle loses bitwise equality
    # to CPU fusion's FMA contraction.  Compiled-vs-compiled is the
    # configuration the engine actually runs in (everything under scan).
    run_kernel = jax.jit(lambda s, st_, t: fused_tick(ctx, cfg, s, st_, t))
    run_ref = jax.jit(
        lambda s, st_, t: fused_outputs_ref(ctx, cfg, s, st_, t))
    # ticks 0..29 cover cold start, active sharing, and three epoch
    # boundaries (sym_win_ticks=10: ticks 9, 19, 29)
    for tick in range(30):
        starts = stage_starts(ctx, state, tick)
        out = run_kernel(starts, state, jnp.int32(tick))
        ref = run_ref(starts, state, jnp.int32(tick))
        for f in out._fields:
            assert np.array_equal(np.asarray(getattr(out, f)),
                                  np.asarray(getattr(ref, f))), \
                f"tick {tick}: {f}"
        state, _ = engine_tick_xla(ctx, cfg, state, tick)


def test_kernel_onehot_segsum_allclose():
    """The dense one-hot segsum mode (the compiled-TPU shape of the
    reductions) reassociates adds: allclose, and int outputs exact."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, sym_on=True)
    st = build_static(topo, wl, "ecmp", seed=3, dt=cfg.dt, deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    state = init_state(ctx, jax.random.PRNGKey(0))
    scatter = jax.jit(
        lambda s, st_, t: fused_tick(ctx, cfg, s, st_, t, segsum="scatter"))
    onehot = jax.jit(
        lambda s, st_, t: fused_tick(ctx, cfg, s, st_, t, segsum="onehot"))
    for tick in range(12):
        starts = stage_starts(ctx, state, tick)
        a = scatter(starts, state, jnp.int32(tick))
        b = onehot(starts, state, jnp.int32(tick))
        for f in a._fields:
            x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
            if x.dtype.kind == "i":
                assert np.array_equal(x, y), f"tick {tick}: {f}"
            else:
                np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5,
                                           err_msg=f"tick {tick}: {f}")
        state, _ = engine_tick_xla(ctx, cfg, state, tick)


# ------------------------------------------------ whole-run, tick-for-tick
@pytest.mark.parametrize("variant", [
    dict(), dict(sym_on=True), dict(pq_on=True), dict(share_policy="pq")])
def test_backend_pallas_matches_xla_run(variant):
    topo, wl = _small()
    cfg = SimParams(n_ticks=500, window=16, **variant)
    x = simulate(topo, wl, cfg, routing="ecmp", seed=3)
    p = simulate(topo, wl, cfg._replace(backend="pallas"), routing="ecmp",
                 seed=3)
    _assert_results_equal(x, p, f"pallas vs xla {variant}")


def test_backend_pallas_grid_matches_xla_grid():
    """The fused tick composes with the grid executor: knob lanes (sym and
    pq gates toggled) stay bitwise-equal to the XLA grid."""
    topo, wl = _small()
    base = SimParams(n_ticks=300, window=16)
    pts = [base, base._replace(sym_on=True), base._replace(pq_on=True)]
    x = simulate_grid(topo, wl, base.structure(),
                      [p.knobs() for p in pts], seeds=(0, 1))
    p = simulate_grid(topo, wl, base._replace(backend="pallas").structure(),
                      [p.knobs() for p in pts], seeds=(0, 1))
    _assert_results_equal(x, p, "pallas grid vs xla grid")


# -------------------------------------------------- dispatch and fallback
def test_wfq_drr_fall_back_to_xla_path():
    for policy in ("wfq", "drr"):
        cfg = SimParams(share_policy=policy, backend="pallas")
        assert resolve_backend(cfg) == "xla"
        topo, wl = _small()
        run = lambda c: simulate(topo, wl, c, routing="ecmp", seed=3)
        _assert_results_equal(
            run(SimParams(n_ticks=200, window=8, share_policy=policy)),
            run(SimParams(n_ticks=200, window=8, share_policy=policy,
                          backend="pallas")),
            f"{policy} fallback")
    assert resolve_backend(SimParams(backend="pallas")) == "pallas"
    assert resolve_backend(SimParams()) == "xla"


def test_unknown_backend_rejected():
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, backend="bogus")
    with pytest.raises(ValueError, match="backend"):
        simulate(topo, wl, cfg, routing="ecmp", seed=0)
    with pytest.raises(ValueError, match="backend"):
        simulate_grid(topo, wl, cfg.structure(), [cfg.knobs()])


# --------------------------------------------------------- golden chain
def test_golden_table1_pallas():
    """Acceptance: the pallas backend reproduces the seed golden finish
    ticks on Table 1 (ecmp, sym off/on) — the chain stays bit-for-bit."""
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64, backend="pallas")
    base = simulate(topo, wl, cfg, routing="ecmp", seed=3)
    assert int(base.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_base"]
    sym = simulate(topo, wl, cfg._replace(sym_on=True), routing="ecmp",
                   seed=3)
    assert int(sym.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_sym"]


@pytest.mark.slow
def test_golden_table1_pallas_balanced_and_pq():
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64, backend="pallas")
    bal = simulate(topo, wl, cfg._replace(sym_on=True), routing="balanced",
                   seed=3)
    assert int(bal.job_finish_ticks[0]) == GOLDEN_JOB["balanced_sym"]
    pq = simulate(topo, wl, cfg._replace(pq_on=True), routing="ecmp", seed=3)
    assert int(pq.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_pq"]


# ---------------------------------------------- tiled grid kernel (blk)
def _count_pallas_calls(jaxpr):
    """Recursively count pallas_call eqns (and collect their grids)."""
    found = []

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                found.append(eqn.params.get("grid_mapping"))
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    walk(v.jaxpr)
                elif isinstance(v, (tuple, list)):
                    for u in v:
                        if hasattr(u, "jaxpr"):
                            walk(u.jaxpr)
    walk(jaxpr)
    return found


@pytest.mark.parametrize("blk", [16, 24, 4096])
def test_tiled_blk_sweep_matches_staged(blk):
    """blk in {divides FW=64, doesn't divide, >= FW (untiled)}: the tiled
    onehot grid kernel matches the staged engine through whole runs —
    int outputs exact, float series allclose (dense reductions and
    cross-block partial accumulation reassociate adds)."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=300, window=8)
    x = simulate(topo, wl, cfg._replace(sym_on=True), routing="ecmp", seed=3)
    t = simulate(topo, wl,
                 cfg._replace(sym_on=True, backend="pallas",
                              segsum="onehot", blk=blk),
                 routing="ecmp", seed=3)
    for f in x._fields:
        a, b = np.asarray(getattr(x, f)), np.asarray(getattr(t, f))
        if a.dtype.kind == "i":
            assert np.array_equal(a, b), f"blk={blk}: {f}"
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5,
                                       err_msg=f"blk={blk}: {f}")


def test_blk_requires_onehot():
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, backend="pallas", blk=16)
    with pytest.raises(ValueError, match="onehot"):
        simulate(topo, wl, cfg, routing="ecmp", seed=0)


# -------------------------------------------- multi-tick window (fusion)
@pytest.mark.parametrize("tw", [1, 5, 7])
def test_tick_window_sweep_matches_staged(tw):
    """tick_window in {1, divides record_every=20, doesn't divide}: the
    multi-tick window kernel stays bit-for-bit with the staged engine
    (the kernel body replays the stage functions per tick, so op order
    is identical)."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=300, window=8, record_every=20)
    for variant in (dict(), dict(sym_on=True), dict(pq_on=True)):
        x = simulate(topo, wl, cfg._replace(**variant), routing="ecmp",
                     seed=3)
        w = simulate(topo, wl,
                     cfg._replace(backend="pallas", tick_window=tw,
                                  **variant),
                     routing="ecmp", seed=3)
        _assert_results_equal(x, w, f"tick_window={tw} {variant}")


def test_tick_window_requires_pallas_backend():
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, tick_window=5)
    with pytest.raises(ValueError, match="pallas"):
        simulate(topo, wl, cfg, routing="ecmp", seed=0)
    # wfq falls back to the staged XLA path -> same rejection
    cfg = cfg._replace(backend="pallas", share_policy="wfq")
    with pytest.raises(ValueError, match="pallas"):
        simulate(topo, wl, cfg, routing="ecmp", seed=0)


def test_tick_window_combines_with_blk_tiling():
    """blk + tick_window combine: plan_tiling routes the config through
    the window kernel (tiling normalizes to None — windowing already
    amortizes the state traffic), and the onehot reductions there stay
    int-exact / float-allclose vs the staged engine."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=300, window=8, record_every=20, sym_on=True)
    x = simulate(topo, wl, cfg, routing="ecmp", seed=3)
    c = simulate(topo, wl,
                 cfg._replace(backend="pallas", segsum="onehot", blk=16,
                              tick_window=5),
                 routing="ecmp", seed=3)
    for f in x._fields:
        a, b = np.asarray(getattr(x, f)), np.asarray(getattr(c, f))
        if a.dtype.kind in "iub":
            assert np.array_equal(a, b), f"blk+tick_window: {f}"
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5,
                                       err_msg=f"blk+tick_window: {f}")


def test_wfq_fallback_warns_once():
    from repro.core.netsim import stages

    topo, wl = _small()
    cfg = SimParams(n_ticks=40, window=8, backend="pallas",
                    share_policy="wfq")
    stages._FALLBACK_WARNED.discard("wfq")
    with pytest.warns(UserWarning, match="falls back"):
        simulate(topo, wl, cfg, routing="ecmp", seed=0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # second resolve must stay silent
        assert resolve_backend(cfg) == "xla"


# ------------------------------------- lane batching: ONE kernel dispatch
def test_grid_lanes_dispatch_single_pallas_call():
    """A simulate_grid batch of 8 lanes through the tiled onehot kernel
    traces to exactly ONE pallas_call whose grid is lane-leading
    [lanes, sweeps, FW_blocks] — vmap batches the grid, it does not
    replicate the kernel."""
    from repro.core.netsim import simulator as sim

    topo, wl = _small()
    base = SimParams(n_ticks=40, window=8, backend="pallas",
                     segsum="onehot", blk=16)
    struct = base.structure()
    pts = [base._replace(sym_on=bool(i % 2)).knobs() for i in range(4)]
    from repro.core.netsim.params import stack_knobs
    knobs = stack_knobs(pts)
    st = build_static(topo, wl, "ecmp", seed=3, dt=base.dt,
                      deploy=base.deploy)
    wla = wl_arrays(wl, base.dt)
    st_stack = jax.tree.map(lambda x: jnp.stack([x, x]), st)
    keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])

    jx = jax.make_jaxpr(
        lambda s, kn, ky: sim._grid_impl(s, wla, struct, kn, ky))(
            st_stack, knobs, keys)
    calls = _count_pallas_calls(jx.jaxpr)
    assert len(calls) == 1, f"expected 1 pallas_call, got {len(calls)}"
    grid = calls[0].grid
    FW = wla.src.shape[0] * base.window
    nb = -(-FW // 16)
    assert grid[0] == 8, f"lane axis not leading: grid={grid}"   # 4 knobs x 2 seeds
    assert tuple(grid[1:]) == (4, nb), f"grid={grid}"


def test_window_kernel_single_dispatch_under_grid():
    """The multi-tick window kernel also batches to one pallas_call per
    scan body under an 8-lane grid."""
    from repro.core.netsim import simulator as sim
    from repro.core.netsim.params import stack_knobs

    topo, wl = _small()
    base = SimParams(n_ticks=40, window=8, record_every=20,
                     backend="pallas", tick_window=5)
    struct = base.structure()
    knobs = stack_knobs([base._replace(sym_on=bool(i % 2)).knobs()
                         for i in range(8)])
    st = build_static(topo, wl, "ecmp", seed=3, dt=base.dt,
                      deploy=base.deploy)
    wla = wl_arrays(wl, base.dt)
    st_stack = jax.tree.map(lambda x: x[None], st)
    keys = jax.random.PRNGKey(0)[None]

    jx = jax.make_jaxpr(
        lambda s, kn, ky: sim._grid_impl(s, wla, struct, kn, ky))(
            st_stack, knobs, keys)
    calls = _count_pallas_calls(jx.jaxpr)
    assert len(calls) == 1, f"expected 1 pallas_call, got {len(calls)}"


# --------------------------------------------- Mosaic-readiness (static)
def test_tiled_onehot_stablehlo_scatter_free_and_gather_free():
    """CI Mosaic gate: the tiled onehot kernel's lowering contains NO
    scatter ops AND NO gather ops — the dense segment reductions plus
    the iota-select null-link zeroing removed every vector scatter, and
    the packed per-block route/chunk/ECMP tables (streamed via BlockSpec
    with scalar-prefetched per-block valid counts) removed every gather —
    and the full 8-lane grid dispatch is a single pallas_call."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=40, window=8, sym_on=True)
    st = build_static(topo, wl, "ecmp", seed=3, dt=cfg.dt, deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    state = init_state(ctx, jax.random.PRNGKey(0))
    starts = stage_starts(ctx, state, 0)

    def tiled(s, st_, t):
        return fused_tick(ctx, cfg, s, st_, t, segsum="onehot", blk=16)

    batched = jax.vmap(tiled, in_axes=(None, None, 0))
    ticks = jnp.arange(8, dtype=jnp.int32)
    jx = jax.make_jaxpr(batched)(starts, state, ticks)
    assert len(_count_pallas_calls(jx.jaxpr)) == 1
    txt = jax.jit(batched).trace(starts, state, ticks).lower(
        lowering_platforms=("tpu",)).as_text()
    n_scatter = txt.count("stablehlo.scatter")
    assert n_scatter == 0, f"{n_scatter} scatter ops in tiled onehot HLO"
    n_gather = txt.count("stablehlo.gather") + txt.count("dynamic_gather")
    assert n_gather == 0, f"{n_gather} gather ops in tiled onehot HLO"


def test_golden_table1_tick_window_and_tiled():
    """Acceptance: the multi-tick window kernel (scatter, bit-for-bit),
    the tiled onehot grid kernel (allclose floats; finish ticks are
    ints), and the combined blk x tick_window config all land the seed
    golden finish ticks on Table 1."""
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64, backend="pallas")
    for c in (cfg._replace(tick_window=5),
              cfg._replace(segsum="onehot", blk=256),
              cfg._replace(segsum="onehot", blk=256, tick_window=5)):
        base = simulate(topo, wl, c, routing="ecmp", seed=3)
        assert int(base.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_base"]
        sym = simulate(topo, wl, c._replace(sym_on=True), routing="ecmp",
                       seed=3)
        assert int(sym.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_sym"]


@pytest.mark.slow
def test_golden_table1_tick_window_balanced_and_pq():
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64, backend="pallas",
                    tick_window=5)
    bal = simulate(topo, wl, cfg._replace(sym_on=True), routing="balanced",
                   seed=3)
    assert int(bal.job_finish_ticks[0]) == GOLDEN_JOB["balanced_sym"]
    pq = simulate(topo, wl, cfg._replace(pq_on=True), routing="ecmp", seed=3)
    assert int(pq.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_pq"]


def test_window_kernel_bitwise_vs_window_ref():
    """Direct window-vs-oracle check on a nontrivial mid-run state: one
    engine_window_fused call equals n staged ticks, bitwise (both sides
    jitted — same contract as the single-tick oracle tests)."""
    from repro.kernels.netsim_tick import window_ref
    from repro.kernels.netsim_tick.ops import engine_window_fused

    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, sym_on=True, backend="pallas",
                    tick_window=5)
    st = build_static(topo, wl, "ecmp", seed=3, dt=cfg.dt, deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    from repro.core.netsim.params import merge_params
    struct, knobs = cfg.split()
    ecfg = merge_params(struct, knobs)
    state = init_state(ctx, jax.random.PRNGKey(0))
    # advance 20 ticks so queues/Symphony windows are warm
    for t in range(20):
        state, _ = engine_tick_xla(ctx, ecfg, state, t)
    run_k = jax.jit(lambda s, t: engine_window_fused(ctx, ecfg, s, t, 5))
    run_r = jax.jit(lambda s, t: window_ref(ctx, ecfg, s, t, 5))
    ks, ksmp = run_k(state, jnp.int32(20))
    rs, rsmp = run_r(state, jnp.int32(20))
    for f in ks._fields:
        assert np.array_equal(np.asarray(getattr(ks, f)),
                              np.asarray(getattr(rs, f))), f
    for i, (a, b) in enumerate(zip(ksmp, rsmp)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), f"sample[{i}]"
