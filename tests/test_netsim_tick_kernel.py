"""Equivalence tests for the fused ``kernels/netsim_tick`` Pallas kernel.

The staged XLA engine is the golden reference: in interpret mode with
``segsum="scatter"`` the kernel must match it **bit-for-bit**, both
per-output on single ticks and tick-for-tick through whole runs — the
seed golden chain (Table-1 finish-tick constants) must hold unchanged
under ``backend="pallas"``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.netsim import (SimParams, WorkloadBuilder, build_static,
                               make_leaf_spine, simulate, simulate_grid)
from repro.core.netsim.simulator import wl_arrays
from repro.core.netsim.stages import (engine_tick, engine_tick_xla,
                                      init_state, make_ctx, resolve_backend,
                                      stage_starts)
from repro.kernels.netsim_tick import (fused_outputs_ref, fused_tick,
                                       engine_tick_fused)

# Same constants as tests/test_netsim_engine.py: captured from the seed
# engine on the Table-1 scenario.  The pallas backend must reproduce them.
GOLDEN_JOB = {"ecmp_base": 10757, "ecmp_sym": 7900,
              "balanced_sym": 2239, "ecmp_pq": 10303}


def _table1():
    topo = make_leaf_spine(32, 4, 4)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(32)), ring_size=8, chunk_bytes=1e6,
                   passes=2, barrier=False)
    return topo, b.build()


def _small():
    topo = make_leaf_spine(8, 2, 2)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(8)), ring_size=4, chunk_bytes=2e5,
                   passes=1, barrier=False)
    return topo, b.build()


def _assert_results_equal(a, b, what):
    for f in a._fields:
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f"{what}: {f}"


# ------------------------------------------------- single-tick, per-output
@pytest.mark.parametrize("variant", [
    dict(), dict(sym_on=True), dict(pq_on=True), dict(share_policy="pq")])
def test_kernel_outputs_bitwise_vs_stage_oracle(variant):
    """Every kernel output equals the stage-function oracle, bitwise, on a
    nontrivial mid-run state — including a sym-window epoch tick."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, **variant)
    st = build_static(topo, wl, "ecmp", seed=3, dt=cfg.dt, deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    state = init_state(ctx, jax.random.PRNGKey(0))
    # Both sides jitted: the kernel body always compiles as one XLA
    # computation, and an eager (op-by-op) oracle loses bitwise equality
    # to CPU fusion's FMA contraction.  Compiled-vs-compiled is the
    # configuration the engine actually runs in (everything under scan).
    run_kernel = jax.jit(lambda s, st_, t: fused_tick(ctx, cfg, s, st_, t))
    run_ref = jax.jit(
        lambda s, st_, t: fused_outputs_ref(ctx, cfg, s, st_, t))
    # ticks 0..29 cover cold start, active sharing, and three epoch
    # boundaries (sym_win_ticks=10: ticks 9, 19, 29)
    for tick in range(30):
        starts = stage_starts(ctx, state, tick)
        out = run_kernel(starts, state, jnp.int32(tick))
        ref = run_ref(starts, state, jnp.int32(tick))
        for f in out._fields:
            assert np.array_equal(np.asarray(getattr(out, f)),
                                  np.asarray(getattr(ref, f))), \
                f"tick {tick}: {f}"
        state, _ = engine_tick_xla(ctx, cfg, state, tick)


def test_kernel_onehot_segsum_allclose():
    """The dense one-hot segsum mode (the compiled-TPU shape of the
    reductions) reassociates adds: allclose, and int outputs exact."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, sym_on=True)
    st = build_static(topo, wl, "ecmp", seed=3, dt=cfg.dt, deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    state = init_state(ctx, jax.random.PRNGKey(0))
    scatter = jax.jit(
        lambda s, st_, t: fused_tick(ctx, cfg, s, st_, t, segsum="scatter"))
    onehot = jax.jit(
        lambda s, st_, t: fused_tick(ctx, cfg, s, st_, t, segsum="onehot"))
    for tick in range(12):
        starts = stage_starts(ctx, state, tick)
        a = scatter(starts, state, jnp.int32(tick))
        b = onehot(starts, state, jnp.int32(tick))
        for f in a._fields:
            x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
            if x.dtype.kind == "i":
                assert np.array_equal(x, y), f"tick {tick}: {f}"
            else:
                np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5,
                                           err_msg=f"tick {tick}: {f}")
        state, _ = engine_tick_xla(ctx, cfg, state, tick)


# ------------------------------------------------ whole-run, tick-for-tick
@pytest.mark.parametrize("variant", [
    dict(), dict(sym_on=True), dict(pq_on=True), dict(share_policy="pq")])
def test_backend_pallas_matches_xla_run(variant):
    topo, wl = _small()
    cfg = SimParams(n_ticks=500, window=16, **variant)
    x = simulate(topo, wl, cfg, routing="ecmp", seed=3)
    p = simulate(topo, wl, cfg._replace(backend="pallas"), routing="ecmp",
                 seed=3)
    _assert_results_equal(x, p, f"pallas vs xla {variant}")


def test_backend_pallas_grid_matches_xla_grid():
    """The fused tick composes with the grid executor: knob lanes (sym and
    pq gates toggled) stay bitwise-equal to the XLA grid."""
    topo, wl = _small()
    base = SimParams(n_ticks=300, window=16)
    pts = [base, base._replace(sym_on=True), base._replace(pq_on=True)]
    x = simulate_grid(topo, wl, base.structure(),
                      [p.knobs() for p in pts], seeds=(0, 1))
    p = simulate_grid(topo, wl, base._replace(backend="pallas").structure(),
                      [p.knobs() for p in pts], seeds=(0, 1))
    _assert_results_equal(x, p, "pallas grid vs xla grid")


# -------------------------------------------------- dispatch and fallback
def test_wfq_drr_fall_back_to_xla_path():
    for policy in ("wfq", "drr"):
        cfg = SimParams(share_policy=policy, backend="pallas")
        assert resolve_backend(cfg) == "xla"
        topo, wl = _small()
        run = lambda c: simulate(topo, wl, c, routing="ecmp", seed=3)
        _assert_results_equal(
            run(SimParams(n_ticks=200, window=8, share_policy=policy)),
            run(SimParams(n_ticks=200, window=8, share_policy=policy,
                          backend="pallas")),
            f"{policy} fallback")
    assert resolve_backend(SimParams(backend="pallas")) == "pallas"
    assert resolve_backend(SimParams()) == "xla"


def test_unknown_backend_rejected():
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, backend="bogus")
    with pytest.raises(ValueError, match="backend"):
        simulate(topo, wl, cfg, routing="ecmp", seed=0)
    with pytest.raises(ValueError, match="backend"):
        simulate_grid(topo, wl, cfg.structure(), [cfg.knobs()])


# --------------------------------------------------------- golden chain
def test_golden_table1_pallas():
    """Acceptance: the pallas backend reproduces the seed golden finish
    ticks on Table 1 (ecmp, sym off/on) — the chain stays bit-for-bit."""
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64, backend="pallas")
    base = simulate(topo, wl, cfg, routing="ecmp", seed=3)
    assert int(base.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_base"]
    sym = simulate(topo, wl, cfg._replace(sym_on=True), routing="ecmp",
                   seed=3)
    assert int(sym.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_sym"]


@pytest.mark.slow
def test_golden_table1_pallas_balanced_and_pq():
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64, backend="pallas")
    bal = simulate(topo, wl, cfg._replace(sym_on=True), routing="balanced",
                   seed=3)
    assert int(bal.job_finish_ticks[0]) == GOLDEN_JOB["balanced_sym"]
    pq = simulate(topo, wl, cfg._replace(pq_on=True), routing="ecmp", seed=3)
    assert int(pq.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_pq"]
