"""Direct unit tests for `repro.core.netsim.metrics`.

The metrics module was previously exercised only through the benchmark
scripts; these tests pin its semantics on hand-built SimResult-shaped
inputs (no simulation runs needed) plus one tiny end-to-end run for the
masked-throughput contract.
"""
import numpy as np
import pytest

from repro.core.netsim import (SimParams, SimResult, WorkloadBuilder,
                               make_leaf_spine, metrics, simulate)
from repro.core.netsim.simulator import I32MAX, WindowSamples


def _res(**kw):
    """A hand-built single-run SimResult (J=2 jobs, T=4 samples)."""
    base = dict(
        finish_ticks=np.asarray([40, 50, 60, 70,          # job 0 flows
                                 80, 80, 80, 80], np.int32),  # job 1 flows
        job_finish_ticks=np.asarray([70, I32MAX], np.int32),
        ts_min_wire=np.asarray([[0, 0], [1, 0], [3, 0], [5, 0]], np.int32),
        ts_max_wire=np.asarray([[1, -1], [3, -1], [5, -1], [7, -1]], np.int32),
        ts_done_min=np.asarray([[0, 0], [1, 0], [2, 0], [4, 0]], np.int32),
        ts_throughput=np.asarray(
            [[1e9, 0.0], [2e9, 0.0], [4e9, 0.0], [1e9, 0.0]], np.float32),
        ts_qmax=np.asarray([0.0, 3e4, 1e4, 0.0], np.float32),
        ts_alpha_max=np.asarray([1.0, 2.5, 1.5, 1.0], np.float32),
    )
    base.update(kw)
    return SimResult(**base)


def _wl2():
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(4)), ring_size=4, chunk_bytes=1e6,
                   passes=1, barrier=False)
    b.add_ring_job(hosts=list(range(4, 8)), ring_size=4, chunk_bytes=1e6,
                   passes=1, barrier=False, start_time=2e-4)
    return b.build()


def test_cct_seconds_masks_unfinished_and_subtracts_start():
    wl = _wl2()
    cfg = SimParams(n_ticks=100, window=8)
    res = _res()
    cct = metrics.cct_seconds(res, wl, cfg)
    # job 0: finish tick 70, started at t=0
    assert cct[0] == pytest.approx(70 * cfg.dt)
    # job 1 never finished -> nan
    assert np.isnan(cct[1])
    # a finished job 1 subtracts its 2e-4 s arrival time
    res2 = _res(job_finish_ticks=np.asarray([70, 50], np.int32))
    cct2 = metrics.cct_seconds(res2, wl, cfg)
    assert cct2[1] == pytest.approx(50 * cfg.dt - 2e-4)


def test_overlap_series_and_max():
    cfg = SimParams(n_ticks=80, window=8, record_every=20)
    res = _res()
    t, ov = metrics.overlap_series(res, cfg, job=0)
    # overlap = max_wire - min_wire + 1 where active
    assert ov.tolist() == [2, 3, 3, 3]
    assert t[0] == pytest.approx(cfg.record_every * cfg.dt)
    assert t[-1] == pytest.approx(4 * cfg.record_every * cfg.dt)
    # job 1 never has an active step (max_wire = -1 sentinel)
    _, ov1 = metrics.overlap_series(res, cfg, job=1)
    assert ov1.tolist() == [0, 0, 0, 0]
    assert metrics.max_overlap(res, cfg, job=0) == 3


def test_step_completion_times():
    cfg = SimParams(n_ticks=80, window=8, record_every=20)
    times = metrics.step_completion_times(_res(), cfg, job=0)
    # done_min advanced 0->1->2->4: one step at samples 1 and 2, two at 3
    t = (np.arange(4) + 1.0) * cfg.record_every * cfg.dt
    assert times.tolist() == pytest.approx([t[1], t[2], t[3], t[3]])


def test_flow_span_seconds():
    wl = _wl2()
    cfg = SimParams(n_ticks=100, window=8)
    # job 0 owns flows 0..3 (ticks 40..70): span 30 ticks
    span = metrics.flow_span_seconds(_res(), wl, cfg, job=0)
    assert span == pytest.approx(30 * cfg.dt)


def test_ideal_cct_serial_steps():
    wl = _wl2()
    # ring of 4, 1 pass, no barrier: 1 segment of 2*(4-1)*1 = 6 serial
    # steps, each moving one chunk_bytes-sized chunk per member
    link = 100e9
    got = metrics.ideal_cct(wl, job=0, link_bps=link)
    assert got == pytest.approx(6 * 1e6 / link)
    # compute gaps add passes * gap seconds
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(4)), ring_size=4, chunk_bytes=1e6,
                   passes=2, barrier=True, compute_gap=1e-3)
    wl2 = b.build()
    assert metrics.ideal_cct(wl2, job=0, link_bps=link) == pytest.approx(
        2 * 6 * 1e6 / link + 2 * 1e-3)


def test_window_summary_reductions():
    cfg = SimParams(n_ticks=80, window=8, record_every=20)
    r = _res()
    stats = metrics.window_summary(
        WindowSamples(ts_min_wire=r.ts_min_wire, ts_max_wire=r.ts_max_wire,
                      ts_done_min=r.ts_done_min,
                      ts_throughput=r.ts_throughput,
                      ts_qmax=r.ts_qmax, ts_alpha_max=r.ts_alpha_max))
    assert stats.alpha_max == pytest.approx(2.5)       # max over window
    assert stats.alpha_last == pytest.approx(1.0)      # final sample
    assert stats.qmax == pytest.approx(3e4)
    assert stats.q_last == pytest.approx(0.0)
    assert stats.tput == pytest.approx([2e9, 0.0])     # window mean per job
    assert stats.tput_last == pytest.approx([1e9, 0.0])
    assert stats.done_min.tolist() == [4, 0]
    assert stats.overlap.tolist() == [3, 0]            # idle job -> 0


def test_ts_throughput_masked_per_job_sum():
    """The engine's ts_throughput is the per-job sum of delivered bytes/s:
    job masks partition the total, and a job that has finished (or not
    started) contributes zero."""
    topo = make_leaf_spine(8, 2, 2)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(4)), ring_size=4, chunk_bytes=1e6,
                   passes=1, barrier=False)
    b.add_ring_job(hosts=list(range(4, 8)), ring_size=4, chunk_bytes=2e6,
                   passes=1, barrier=False)
    wl = b.build()
    cfg = SimParams(n_ticks=1_500, window=8, record_every=10)
    res = simulate(topo, wl, cfg, routing="ecmp", seed=0)
    tput = np.asarray(res.ts_throughput)               # [T, J]
    assert tput.shape == (150, 2)
    assert (tput >= 0).all() and np.isfinite(tput).all()
    jf = np.asarray(res.job_finish_ticks)
    assert (jf != I32MAX).all()
    # after a job finishes, its throughput samples are exactly zero
    for j in range(2):
        done_sample = int(jf[j]) // cfg.record_every + 1
        assert tput[done_sample:, j] == pytest.approx(0.0)
        assert tput[:done_sample, j].max() > 0
    # total delivered bytes per job ~ the volume the ring actually moves
    # (4 members x 6 steps x one chunk each; the sampled-rate integral
    # carries record-grid quantization error)
    for j, chunk in ((0, 1e6), (1, 2e6)):
        delivered = float(tput[:, j].sum()) * cfg.record_every * cfg.dt
        moved = 6 * 4 * chunk
        assert delivered == pytest.approx(moved, rel=0.1)
