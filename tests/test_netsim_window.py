"""Tests for the online control plane (PR 10).

* Windowed checkpoint/resume: `init_state` + `run_window` replayed over
  any window split is bit-for-bit identical to the one-shot `simulate`
  on the Table-1 goldens — integer outputs and `ts_alpha_max` — for the
  XLA staged path AND the fused pallas path with `tick_window`/`blk`
  tiling active.
* The `step(state, action)` API: knob retunes between windows never
  retrace (`core_trace_count` advances by exactly 1), stepping with
  unchanged knobs matches the one-shot run, and checkpoint/restore
  rewinds deterministically.
* Dependency-triggered arrivals: `set_trigger` releases a job only when
  its dependency completes (plus delay), `add_poisson_churn` is
  reproducible, and triggered workloads run unchanged under the grid
  executor.
"""
import jax
import numpy as np
import pytest

from repro.core.netsim import (SimController, SimParams, WorkloadBuilder,
                               apply_action, build_static, core_trace_count,
                               init_state, make_leaf_spine, run_window,
                               simulate, simulate_grid)
from repro.core.netsim.simulator import I32MAX, _resolve_routing, wl_arrays

# Table-1 goldens (captured from the seed engine; see test_netsim_engine).
GOLDEN_JOB = {"ecmp_base": 10757, "ecmp_sym": 7900,
              "balanced_sym": 2239, "ecmp_pq": 10303}


def _table1():
    topo = make_leaf_spine(32, 4, 4)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(32)), ring_size=8, chunk_bytes=1e6,
                   passes=2, barrier=False)
    return topo, b.build()


def _small():
    topo = make_leaf_spine(8, 2, 2)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(8)), ring_size=4, chunk_bytes=1e6,
                   passes=1, barrier=False)
    return topo, b.build()


def _prep(topo, wl, cfg, routing="ecmp", seed=0):
    """The same static/knob split `simulate` performs internally."""
    cfg, mode = _resolve_routing(cfg, routing)
    st = build_static(topo, wl, mode, seed, dt=cfg.dt, deploy=cfg.deploy)
    struct, knobs = cfg.split()
    return st, wl_arrays(wl, cfg.dt), struct, knobs


def _run_split(st, wla, struct, knobs, seed, splits):
    """Resume across `splits` windows; returns (state, concatenated samples)."""
    state = init_state(st, wla, struct, seed)
    chunks = []
    for n in splits:
        state, samples = run_window(st, wla, struct, knobs, state, n)
        chunks.append(samples)
    cat = jax.tree.map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs]), *chunks)
    return state, cat


def _assert_resume_equal(one, state, samples, n_ticks):
    assert int(state.tick) == n_ticks
    assert np.array_equal(np.asarray(state.engine.finish),
                          np.asarray(one.finish_ticks))
    assert np.array_equal(np.asarray(state.engine.job_finish),
                          np.asarray(one.job_finish_ticks))
    assert np.array_equal(np.asarray(samples.ts_alpha_max),
                          np.asarray(one.ts_alpha_max))
    assert np.array_equal(np.asarray(samples.ts_done_min),
                          np.asarray(one.ts_done_min))


# ------------------------------------------------- resume equivalence (golden)
def test_resume_equivalence_table1_goldens():
    """Uneven window splits of the 20k-tick Table-1 run reproduce the
    one-shot goldens bit-for-bit (ecmp base + sym).  The integer outputs
    are pinned by the golden constants; the sym variant additionally
    checks the sampled series bitwise against a one-shot run."""
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64)
    splits = (6_400, 6_400, 6_400, 800)         # uneven, sums to 20_000

    st, wla, struct, knobs = _prep(topo, wl, cfg, seed=3)
    state, _ = _run_split(st, wla, struct, knobs, 3, splits)
    assert int(state.engine.job_finish[0]) == GOLDEN_JOB["ecmp_base"]

    sym = cfg._replace(sym_on=True)
    one = simulate(topo, wl, sym, routing="ecmp", seed=3)
    st, wla, struct, knobs = _prep(topo, wl, sym, seed=3)
    state, samples = _run_split(st, wla, struct, knobs, 3, splits)
    assert int(state.engine.job_finish[0]) == GOLDEN_JOB["ecmp_sym"]
    _assert_resume_equal(one, state, samples, cfg.n_ticks)
    # float series concatenate exactly too (same compiled tick program)
    assert np.array_equal(np.asarray(samples.ts_throughput),
                          np.asarray(one.ts_throughput))


@pytest.mark.slow
def test_resume_equivalence_balanced_and_pq():
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64)
    splits = (2_600, 400, 17_000)
    for name, c, routing in (
            ("balanced_sym", cfg._replace(sym_on=True), "balanced"),
            ("ecmp_pq", cfg._replace(pq_on=True), "ecmp")):
        one = simulate(topo, wl, c, routing=routing, seed=3)
        st, wla, struct, knobs = _prep(topo, wl, c, routing=routing, seed=3)
        state, samples = _run_split(st, wla, struct, knobs, 3, splits)
        assert int(state.engine.job_finish[0]) == GOLDEN_JOB[name]
        _assert_resume_equal(one, state, samples, cfg.n_ticks)


def test_resume_equivalence_pallas_tiled():
    """Windowed resume composes with the fused pallas backend with
    multi-tick windows (tick_window=5) and lane tiling (blk=16 < FW=64)
    active — still bit-for-bit vs the one-shot run."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=600, window=8, record_every=10, sym_on=True,
                    backend="pallas", segsum="onehot", tick_window=5, blk=16)
    one = simulate(topo, wl, cfg, routing="ecmp", seed=0)
    st, wla, struct, knobs = _prep(topo, wl, cfg, seed=0)
    state, samples = _run_split(st, wla, struct, knobs, 0,
                                (100, 100, 400))
    _assert_resume_equal(one, state, samples, cfg.n_ticks)


def test_resume_arbitrary_split_matches_oneshot():
    """Window boundaries anywhere on the record grid — including a
    single-record-period window — replay identically."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=400, window=8, record_every=10, sym_on=True)
    one = simulate(topo, wl, cfg, routing="ecmp", seed=1)
    st, wla, struct, knobs = _prep(topo, wl, cfg, seed=1)
    state, samples = _run_split(st, wla, struct, knobs, 1,
                                (10, 30, 200, 150, 10))
    _assert_resume_equal(one, state, samples, cfg.n_ticks)


def test_run_window_validates_tick_grid():
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, record_every=10)
    st, wla, struct, knobs = _prep(topo, wl, cfg)
    state = init_state(st, wla, struct, 0)
    for bad in (0, -10, 15):
        with pytest.raises(ValueError, match="record_every"):
            run_window(st, wla, struct, knobs, state, bad)


# --------------------------------------------------------- step(state, action)
def test_step_one_compile_across_knob_changes():
    """Retuning knobs between windows NEVER retraces the engine: the
    acceptance contract is ONE compile across repeated step() calls with
    different knob values (including Symphony shortcut fields)."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=400, window=8, record_every=10, sym_on=True)
    ctl = SimController(topo, wl, cfg, window_ticks=50, seed=0)
    c0 = core_trace_count()
    for action in (None, {"tau": 0.1}, {"k": 0.02, "tau": 0.3},
                   {"red_pmax": 0.5}, {"alpha_max": 4.0},
                   {"sym_on": False}, {"sym_on": True, "tau": 0.05}):
        ctl.step(action)
    assert core_trace_count() - c0 == 1


def test_step_resume_matches_oneshot():
    """Stepping with unchanged knobs IS the one-shot run, bit-for-bit;
    obs carries the per-window summaries."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=400, window=8, record_every=10, sym_on=True)
    one = simulate(topo, wl, cfg, routing="ecmp", seed=0)
    ctl = SimController(topo, wl, cfg, window_ticks=80, seed=0)
    chunks = []
    for _ in range(5):
        state, obs = ctl.step()
        chunks.append(obs.samples)
    assert obs.tick == 400 and obs.t == pytest.approx(400 * cfg.dt)
    cat = jax.tree.map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs]), *chunks)
    _assert_resume_equal(one, state, cat, cfg.n_ticks)
    # obs flags agree with the engine's completion state
    jf = np.asarray(one.job_finish_ticks)
    assert np.array_equal(obs.job_finished, jf != I32MAX)
    assert obs.done == bool((jf != I32MAX).all())
    assert obs.stats.alpha_max == pytest.approx(
        float(np.asarray(chunks[-1].ts_alpha_max).max()))
    assert obs.stats.tput.shape == (wl.n_jobs,)


def test_checkpoint_restore_rewind():
    """restore() rewinds to a snapshot and replays identically."""
    topo, wl = _small()
    cfg = SimParams(n_ticks=400, window=8, record_every=10, sym_on=True)
    ctl = SimController(topo, wl, cfg, window_ticks=100, seed=0)
    ctl.step()
    snap = ctl.checkpoint()                     # host-side copy at tick 100
    sa, _ = ctl.step()
    ctl.restore(snap)
    sb, _ = ctl.step()
    assert int(sa.tick) == int(sb.tick) == 200
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_controller_window_validation():
    topo, wl = _small()
    cfg = SimParams(n_ticks=100, window=8, record_every=10)
    with pytest.raises(ValueError, match="record_every"):
        SimController(topo, wl, cfg, window_ticks=15)


def test_apply_action_preserves_structure():
    """Actions update values without touching pytree structure or leaf
    dtypes (what makes knob retunes trace-free)."""
    knobs = SimParams().knobs()
    new = apply_action(knobs, {"tau": 0.25, "red_pmax": 0.9, "sym_on": True,
                               "k": 0.01})
    assert jax.tree.structure(new) == jax.tree.structure(knobs)
    for a, b in zip(jax.tree.leaves(knobs), jax.tree.leaves(new)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert np.asarray(a).shape == np.asarray(b).shape
    assert float(new.sym.tau) == pytest.approx(0.25)
    assert float(new.sym.k) == pytest.approx(0.01)
    assert float(new.red_pmax) == pytest.approx(0.9)
    assert float(new.sym_on) == 1.0
    # untouched fields keep their values
    assert float(new.sym.alpha_max) == float(knobs.sym.alpha_max)
    with pytest.raises(ValueError, match="unknown action field"):
        apply_action(knobs, {"bogus": 1.0})
    with pytest.raises(ValueError, match="individually"):
        apply_action(knobs, {"sym": None})


# ------------------------------------------------ dependency-triggered arrivals
def _two_job_wl(trigger=None, collectives=None, delay=0.0):
    b = WorkloadBuilder()
    # barrier=True keeps job 0's passes as separate segments, so a
    # collectives=1 trigger can fire mid-job
    b.add_ring_job(hosts=list(range(8)), ring_size=4, chunk_bytes=1e6,
                   passes=2, barrier=True)
    b.add_ring_job(hosts=list(range(8, 16)), ring_size=4, chunk_bytes=1e6,
                   passes=1, barrier=False)
    if trigger:
        b.set_trigger(1, after_job=0, collectives=collectives, delay=delay)
    return b.build()


def test_trigger_releases_after_dependency():
    topo = make_leaf_spine(16, 2, 2)
    cfg = SimParams(n_ticks=1_600, window=8, record_every=10)

    free = simulate(topo, wl := _two_job_wl(), cfg, routing="ecmp", seed=0)
    jf_free = np.asarray(free.job_finish_ticks)
    trig = simulate(topo, _two_job_wl(trigger=True), cfg, routing="ecmp",
                    seed=0)
    jf = np.asarray(trig.job_finish_ticks)
    # untriggered: both jobs start at t=0, job 1 (1 pass) finishes first;
    # triggered: job 1 is held until job 0 completes every collective.
    assert jf_free[1] < jf_free[0]
    assert jf[1] > jf[0]
    assert jf[1] > jf_free[1]

    # a pure delay shifts the released job exactly (job 0 is done by then,
    # so job 1 replays contention-free at the offset); an immediate (d=0)
    # release is evaluated at the end of the trigger tick, so the shift
    # relative to it is d - 1
    d = 50
    trig_d = simulate(topo, _two_job_wl(trigger=True, delay=d * cfg.dt),
                      cfg, routing="ecmp", seed=0)
    assert int(trig_d.job_finish_ticks[1]) == int(jf[1]) + d - 1

    # triggering on the FIRST collective of the 2-pass job releases earlier
    trig_c1 = simulate(topo, _two_job_wl(trigger=True, collectives=1),
                       cfg, routing="ecmp", seed=0)
    assert int(trig_c1.job_finish_ticks[1]) < int(jf[1])


def test_trigger_resume_and_grid_consistent():
    """Triggers evaluate inside the traced tick, so they compose with
    windowed resume and the one-compile grid executor bit-for-bit."""
    topo = make_leaf_spine(16, 2, 2)
    wl = _two_job_wl(trigger=True, delay=1e-4)
    cfg = SimParams(n_ticks=1_000, window=8, record_every=10, sym_on=True)
    one = simulate(topo, wl, cfg, routing="ecmp", seed=0)
    # windowed resume
    st, wla, struct, knobs = _prep(topo, wl, cfg, seed=0)
    state, samples = _run_split(st, wla, struct, knobs, 0, (300, 100, 600))
    _assert_resume_equal(one, state, samples, cfg.n_ticks)
    # 1-point grid slice
    gres = simulate_grid(topo, wl, struct,
                         jax.tree.map(lambda x: x[None], knobs),
                         seeds=(0,), routing="ecmp")
    assert np.array_equal(np.asarray(gres.job_finish_ticks)[0, 0],
                          np.asarray(one.job_finish_ticks))
    assert np.array_equal(np.asarray(gres.finish_ticks)[0, 0],
                          np.asarray(one.finish_ticks))


def test_trigger_validation():
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(4)), ring_size=4, chunk_bytes=1e6,
                   passes=2, barrier=False)
    b.add_ring_job(hosts=list(range(4, 8)), ring_size=4, chunk_bytes=1e6,
                   passes=1, barrier=False)
    with pytest.raises(ValueError, match="itself"):
        b.set_trigger(0, after_job=0)
    with pytest.raises(ValueError, match="unknown job"):
        b.set_trigger(1, after_job=5)
    with pytest.raises(ValueError, match="collectives"):
        b.set_trigger(1, after_job=0, collectives=0)
    with pytest.raises(ValueError, match="delay"):
        b.set_trigger(1, after_job=0, delay=-1.0)
    # asking for more collectives than the dependency runs fails at build()
    b.set_trigger(1, after_job=0, collectives=3)
    with pytest.raises(ValueError, match="only runs"):
        b.build()


def test_poisson_churn_builder():
    def mk(seed):
        b = WorkloadBuilder()
        b.add_ring_job(hosts=list(range(8)), ring_size=4, chunk_bytes=1e6,
                       passes=1, barrier=False)
        jobs = b.add_poisson_churn(
            [list(range(8, 12)), list(range(12, 16))],
            rate_hz=500.0, horizon_s=0.1, ring_size=4, chunk_bytes=1e5,
            passes=1, seed=seed, max_jobs=3)
        return jobs, b.build()

    jobs, wl = mk(7)
    assert len(jobs) == 3                        # max_jobs honored
    starts = np.asarray(wl.start_time)[jobs]
    assert np.all(np.diff(starts) > 0)           # Poisson arrivals ordered
    assert np.all(starts > 0) and np.all(starts < 0.1)
    assert np.all(np.asarray(wl.trig_job)[jobs] == -1)   # churn = fixed starts
    # reproducible for a seed, different across seeds
    _, wl2 = mk(7)
    assert np.array_equal(np.asarray(wl2.start_time), np.asarray(wl.start_time))
    _, wl3 = mk(8)
    assert not np.array_equal(np.asarray(wl3.start_time)[1:],
                              np.asarray(wl.start_time)[1:])
    with pytest.raises(ValueError, match="rate_hz"):
        WorkloadBuilder().add_poisson_churn([[0, 1]], rate_hz=0.0,
                                            horizon_s=1.0)
    with pytest.raises(ValueError, match="empty host_groups"):
        WorkloadBuilder().add_poisson_churn([], rate_hz=1.0, horizon_s=1.0)
