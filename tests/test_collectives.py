"""Ring collective correctness on 8 virtual devices (subprocess: jax device
count is locked at first init, so multi-device tests run in a child python
with XLA_FLAGS set)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.collectives.ring import (ring_all_gather, ring_all_reduce,
                                    ring_reduce_scatter,
                                    hierarchical_all_reduce)
from repro.collectives.scheduler import sync_grads_local
from repro.compat import make_mesh as _mesh, shard_map

mesh = _mesh((8,), ("data",))
key = jax.random.PRNGKey(0)

# sweep shapes x dtypes x variants; the ring sums the 8 local shards, so the
# expectation is a sum over the shard axis.
for shape in [(8, 16), (16, 7, 3), (64,)]:
    for dtype in [jnp.float32, jnp.bfloat16]:
        x = jax.random.normal(key, shape, jnp.float32).astype(dtype)
        want = np.asarray(
            x.astype(jnp.float32).reshape((8, shape[0] // 8) + shape[1:])
            .sum(0))
        for kw in [{}, {"channels": 2}, {"bidirectional": True}]:
            f = jax.jit(shard_map(
                lambda v: ring_all_reduce(v.astype(jnp.float32), "data", **kw),
                mesh=mesh, in_specs=P("data"), out_specs=P()))
            got = np.asarray(f(x))
            np.testing.assert_allclose(got, want, rtol=2e-2, atol=1e-2)
print("all_reduce sweep OK")

# reduce-scatter + all-gather round trip == all-reduce
x = jax.random.normal(key, (8, 32), jnp.float32)
f = jax.jit(shard_map(
    lambda v: ring_all_gather(ring_reduce_scatter(v, "data"), "data"),
    mesh=mesh, in_specs=P(), out_specs=P()))
np.testing.assert_allclose(np.asarray(f(x))[:8], 8 * np.asarray(x), rtol=1e-5)
print("rs+ag OK")

# hierarchical == flat on a 2x4 mesh
mesh2 = _mesh((2, 4), ("pod", "data"))
x2 = jax.random.normal(key, (8, 40), jnp.float32)
f = jax.jit(shard_map(
    lambda v: hierarchical_all_reduce(v, "data", "pod"),
    mesh=mesh2, in_specs=P(("pod", "data")), out_specs=P()))
np.testing.assert_allclose(np.asarray(f(x2))[0], np.asarray(x2.sum(0)),
                           rtol=1e-4, atol=1e-4)
print("hierarchical OK")

# sync_grads_local pytree == psum, for each mode
grads = {"a": jax.random.normal(key, (8, 6, 5)),
         "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (8, 33))}}
for mode in ["ring", "hierarchical", "psum"]:
    f = jax.jit(shard_map(
        lambda g: sync_grads_local(g, ("pod", "data"), mode=mode,
                                   bucket_bytes=64),
        mesh=mesh2,
        in_specs=({"a": P(("pod", "data")), "b": {"c": P(("pod", "data"))}},),
        out_specs={"a": P(("pod", "data")), "b": {"c": P(("pod", "data"))}}))
    got = f(grads)
    for kpath in ["a"]:
        want = np.asarray(grads[kpath].mean(0, keepdims=True))
        np.testing.assert_allclose(np.asarray(got[kpath])[0:1], want,
                                   rtol=1e-4, atol=1e-4)
print("sync_grads", "OK")

# HLO of ring all-reduce shows the 2(N-1) collective-permute step chain
lw = jax.jit(shard_map(lambda v: ring_all_reduce(v, "data"),
                           mesh=mesh, in_specs=P("data"),
                           out_specs=P())).lower(x)
txt = lw.compile().as_text()
import re
n_cp = len(re.findall(r" collective-permute", txt))
assert n_cp >= 14, n_cp   # 2*(8-1) steps
print("HLO steps OK:", n_cp)
print("ALLPASS")
"""


def test_ring_collectives_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALLPASS" in r.stdout
