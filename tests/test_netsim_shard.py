"""Tests for the multi-device grid dispatch (`simulate_grid(devices=...)`).

Run with a forced CPU mesh to exercise the sharded path::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_netsim_shard.py

On a plain 1-device host the mesh resolves to ``None`` and the
device-dependent tests skip; the resolver/fallback tests still run, so
the file is safe inside the ordinary tier-1 sweep.

Equivalence contract (pinned by ``test_sharded_matches_single_device``):
the integer tick outputs (finish_ticks, job_finish_ticks, ts_min_wire,
ts_max_wire, ts_done_min) and ts_alpha_max are **bit-for-bit** identical
sharded vs unsharded — per-lane scatter order inside the engine does not
depend on how the lane axis is split.  The float32 time series
(ts_throughput, ts_qmax) may drift a few ULPs (~2e-6 relative) because
XLA reassociates the per-lane reductions differently at different batch
sizes; those are compared with allclose.
"""
import os

import jax
import numpy as np
import pytest

from repro.core.netsim import (GRID_AXIS, SimParams, WorkloadBuilder,
                               core_trace_count, grid_from_params,
                               make_leaf_spine, resolve_grid_mesh,
                               simulate_grid, simulate_seeds)

N_DEV = jax.device_count()
multi = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")

EXACT = ("finish_ticks", "job_finish_ticks", "ts_min_wire", "ts_max_wire",
         "ts_done_min", "ts_alpha_max")
CLOSE = ("ts_throughput", "ts_qmax")


@pytest.fixture(scope="module")
def small():
    topo = make_leaf_spine(8, 2, 2)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(8)), ring_size=4, chunk_bytes=1e6,
                   passes=1)
    return topo, b.build()


def _cfgs(cfg, ks):
    return [cfg._replace(sym_on=True, sym=cfg.sym._replace(k=k))
            for k in ks]


def _assert_equiv(ref, got, ctx=""):
    for f in EXACT:
        assert np.array_equal(np.asarray(getattr(ref, f)),
                              np.asarray(getattr(got, f))), (f, ctx)
    for f in CLOSE:
        np.testing.assert_allclose(
            np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
            rtol=1e-5, atol=1e-3, err_msg=f"{f} {ctx}")


# --------------------------------------------------------------- resolver
def test_resolve_none_and_single_device():
    assert resolve_grid_mesh() is None
    assert resolve_grid_mesh(devices=None) is None
    # a 1-device request is a no-op mesh -> normalized to None (plain
    # unsharded dispatch), so "auto" on a 1-device host just works
    assert resolve_grid_mesh(devices=1) is None
    if N_DEV == 1:
        assert resolve_grid_mesh(devices="auto") is None


def test_resolve_rejects_overask():
    with pytest.raises(ValueError, match="devices"):
        resolve_grid_mesh(devices=N_DEV + 1)


@multi
def test_resolve_auto_and_int():
    mesh = resolve_grid_mesh(devices="auto")
    assert mesh is not None and mesh.devices.size == N_DEV
    assert mesh.axis_names == (GRID_AXIS,)
    mesh2 = resolve_grid_mesh(devices=2)
    assert mesh2.devices.size == 2
    # an explicit mesh passes through untouched
    assert resolve_grid_mesh(mesh=mesh2) is mesh2


def test_resolve_rejects_2d_mesh():
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    with pytest.raises(ValueError, match="1-D"):
        resolve_grid_mesh(mesh=Mesh(devs, ("a", "b")))


# ----------------------------------------------------------- equivalence
@multi
def test_sharded_matches_single_device(small):
    """Sharded grid == unsharded grid: int fields bitwise, float32 series
    within a few ULPs (see module docstring) — and ONE engine trace."""
    topo, wl = small
    cfg = SimParams(n_ticks=800, window=8, record_every=10)
    struct, knobs = grid_from_params(
        _cfgs(cfg, (1e-3, 3e-3, 1e-2, 3e-2)))          # K=4
    seeds = [0, 1]                                      # K*S = 8 lanes
    ref = simulate_grid(topo, wl, struct, knobs, seeds, routing="ecmp")
    c0 = core_trace_count()
    got = simulate_grid(topo, wl, struct, knobs, seeds, routing="ecmp",
                        devices="auto")
    assert core_trace_count() - c0 == 1, "sharded grid must be ONE compile"
    _assert_equiv(ref, got)


@multi
def test_sharded_non_divisible_lanes_padded_and_masked(small):
    """K*S = 12 lanes on an 8-device mesh: the executor edge-pads the lane
    axis to 16, dispatches, and slices the padding off — every real lane
    must match the unsharded run and the result keeps its [K, S] shape."""
    topo, wl = small
    cfg = SimParams(n_ticks=600, window=8, record_every=10)
    struct, knobs = grid_from_params(
        _cfgs(cfg, (1e-3, 2e-3, 3e-3, 5e-3, 1e-2, 3e-2)))   # K=6
    seeds = [0, 1]                                           # 12 lanes
    assert (len(seeds) * 6) % N_DEV != 0 or N_DEV == 2
    ref = simulate_grid(topo, wl, struct, knobs, seeds, routing="ecmp")
    got = simulate_grid(topo, wl, struct, knobs, seeds, routing="ecmp",
                        devices="auto")
    assert got.finish_ticks.shape[:2] == (6, 2)
    _assert_equiv(ref, got, ctx="12 lanes / auto mesh")


@multi
def test_sharded_chunking_composes(small):
    """chunk_knobs bounds knob points PER DEVICE: sharded + chunked
    dispatch still reproduces the unsharded result."""
    topo, wl = small
    cfg = SimParams(n_ticks=600, window=8, record_every=10)
    struct, knobs = grid_from_params(
        _cfgs(cfg, (1e-3, 2e-3, 3e-3, 5e-3, 1e-2, 3e-2, 1e-1)))  # K=7
    ref = simulate_grid(topo, wl, struct, knobs, [0], routing="ecmp")
    got = simulate_grid(topo, wl, struct, knobs, [0], routing="ecmp",
                        devices="auto", chunk_knobs=2)
    _assert_equiv(ref, got, ctx="chunk_knobs=2 / auto mesh")


@multi
def test_sharded_devices_int_and_explicit_mesh(small):
    topo, wl = small
    cfg = SimParams(n_ticks=600, window=8, record_every=10)
    struct, knobs = grid_from_params(_cfgs(cfg, (1e-3, 1e-2)))
    ref = simulate_grid(topo, wl, struct, knobs, [0, 1], routing="ecmp")
    got = simulate_grid(topo, wl, struct, knobs, [0, 1], routing="ecmp",
                        devices=2)
    _assert_equiv(ref, got, ctx="devices=2")
    mesh = resolve_grid_mesh(devices=2)
    got2 = simulate_grid(topo, wl, struct, knobs, [0, 1], routing="ecmp",
                         mesh=mesh)
    _assert_equiv(ref, got2, ctx="mesh=2-device")


@multi
def test_sharded_seeds_matches_single_device(small):
    topo, wl = small
    cfg = SimParams(n_ticks=600, window=8, record_every=10, sym_on=True)
    seeds = [0, 1, 2]                   # 3 lanes: non-divisible on 2+ devs
    ref = simulate_seeds(topo, wl, cfg, "ecmp", seeds)
    got = simulate_seeds(topo, wl, cfg, "ecmp", seeds, devices="auto")
    _assert_equiv(ref, got, ctx="simulate_seeds / auto mesh")


def test_devices_none_is_default_path(small):
    """devices=None must stay the exact single-device dispatch: same
    object-level behaviour as not passing the knob at all."""
    topo, wl = small
    cfg = SimParams(n_ticks=400, window=8, record_every=10)
    struct, knobs = grid_from_params(_cfgs(cfg, (1e-3, 1e-2)))
    a = simulate_grid(topo, wl, struct, knobs, [0], routing="ecmp")
    b = simulate_grid(topo, wl, struct, knobs, [0], routing="ecmp",
                      devices=None)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------- bench plumbing
def test_bench_grid_devices_env(monkeypatch):
    from benchmarks import common
    monkeypatch.delenv("BENCH_DEVICES", raising=False)
    assert common.grid_devices() is None
    monkeypatch.setenv("BENCH_DEVICES", "1")
    assert common.grid_devices() is None
    monkeypatch.setenv("BENCH_DEVICES", "auto")
    assert common.grid_devices() == "auto"
    monkeypatch.setenv("BENCH_DEVICES", "4")
    assert common.grid_devices() == 4


def test_cache_key_includes_device_fingerprint(monkeypatch):
    """Single- and multi-device runs must not collide in the result
    cache: the fingerprint (folded into every cached() key) must change
    with the BENCH_DEVICES mesh."""
    from benchmarks import common
    monkeypatch.delenv("BENCH_DEVICES", raising=False)
    fp1 = common.device_fingerprint()
    assert fp1.endswith(":grid1")
    if N_DEV >= 2:
        monkeypatch.setenv("BENCH_DEVICES", "auto")
        fp8 = common.device_fingerprint()
        assert fp8 != fp1 and fp8.endswith(f":grid{N_DEV}")
