"""Network-simulator physics + Symphony effectiveness tests."""
import jax
import numpy as np
import pytest

from repro.core.netsim import (SimParams, WorkloadBuilder, make_leaf_spine,
                               metrics, simulate)


@pytest.fixture(scope="module")
def small():
    topo = make_leaf_spine(8, 2, 2)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(8)), ring_size=4, chunk_bytes=1e6,
                   passes=1)
    return topo, b.build()


def test_balanced_routing_hits_ideal(small):
    topo, wl = small
    cfg = SimParams(n_ticks=2000, window=8, record_every=10)
    res = simulate(topo, wl, cfg, routing="balanced", seed=0)
    cct = metrics.cct_seconds(res, wl, cfg)[0]
    ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)
    assert cct == pytest.approx(ideal, rel=0.02)
    assert metrics.max_overlap(res, cfg) <= 1


def test_conservation_throughput_bounded(small):
    """Delivered job throughput can never exceed aggregate access capacity."""
    topo, wl = small
    cfg = SimParams(n_ticks=2000, window=8, record_every=10)
    res = simulate(topo, wl, cfg, routing="ecmp", seed=1)
    tput = np.asarray(res.ts_throughput)[:, 0]
    assert tput.max() <= 8 * 1.25e9 * 1.001


def test_all_flows_complete(small):
    topo, wl = small
    cfg = SimParams(n_ticks=6000, window=8, record_every=10)
    res = simulate(topo, wl, cfg, routing="ecmp", seed=2)
    assert np.asarray(res.finish_ticks).max() < 2**30


def test_ecmp_seeds_differ(small):
    topo, wl = small
    cfg = SimParams(n_ticks=6000, window=8, record_every=10)
    c1 = metrics.cct_seconds(simulate(topo, wl, cfg, "ecmp", seed=1), wl, cfg)
    c2 = metrics.cct_seconds(simulate(topo, wl, cfg, "ecmp", seed=5), wl, cfg)
    # different seeds -> different path draws (almost surely differ)
    assert c1[0] != c2[0]


@pytest.mark.slow
def test_symphony_clamps_overlap_and_improves_cct():
    """The paper's headline: overlap clamped (Fig. 4a) and CCT reduced."""
    topo = make_leaf_spine(32, 4, 4)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(32)), ring_size=8, chunk_bytes=4e6,
                   passes=4, barrier=False)
    wl = b.build()
    cfg = SimParams(n_ticks=90_000, window=64)
    base = simulate(topo, wl, cfg, routing="ecmp", seed=3)
    sym = simulate(topo, wl, cfg._replace(sym_on=True), routing="ecmp", seed=3)
    mo_b = metrics.max_overlap(base, cfg)
    mo_s = metrics.max_overlap(sym, cfg)
    assert mo_s < mo_b, (mo_s, mo_b)
    assert mo_s <= 8
    cct_b = metrics.cct_seconds(base, wl, cfg)[0]
    cct_s = metrics.cct_seconds(sym, wl, cfg)[0]
    if np.isfinite(cct_b) and np.isfinite(cct_s):
        assert cct_s < cct_b * 1.02


def test_symphony_transparent_when_aligned(small):
    """With balanced routing (no misalignment) Symphony must not hurt."""
    topo, wl = small
    cfg = SimParams(n_ticks=2500, window=8, record_every=10)
    base = simulate(topo, wl, cfg, routing="balanced", seed=0)
    sym = simulate(topo, wl, cfg._replace(sym_on=True), routing="balanced",
                   seed=0)
    c_b = metrics.cct_seconds(base, wl, cfg)[0]
    c_s = metrics.cct_seconds(sym, wl, cfg)[0]
    assert c_s <= c_b * 1.05


def test_two_jobs_isolated_state():
    """Per-job state blocks: a lagging job must not throttle the other."""
    topo = make_leaf_spine(16, 2, 2)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(0, 8)), ring_size=4, chunk_bytes=1e6,
                   passes=2, start_time=0.0)
    b.add_ring_job(hosts=list(range(8, 16)), ring_size=4, chunk_bytes=1e6,
                   passes=2, start_time=0.002)
    wl = b.build()
    cfg = SimParams(n_ticks=8000, window=16, record_every=10, sym_on=True)
    res = simulate(topo, wl, cfg, routing="balanced", seed=0)
    cct = metrics.cct_seconds(res, wl, cfg)
    assert np.isfinite(cct).all()
