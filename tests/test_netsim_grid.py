"""Tests for the SimStructure/RuntimeKnobs split and the batched grid
executor.

* vmap-consistency: ``simulate_grid`` slices are bitwise-equal to
  per-point ``simulate`` calls, and ``simulate_seeds`` to per-seed calls.
* compile discipline: a >=16-point knob grid traces the engine exactly
  once, per-point knob changes never retrace, chunking doesn't retrace.
* the SimParams facade: split/merge round-trip, legacy simulate_core
  call form, structural-mismatch rejection.
* the drr share policy and its registry selection.
* benchmark cache keying (overrides hash + schema invalidation).
"""
import jax
import numpy as np
import pytest

from repro.core.netsim import (SHARE_POLICIES, SimParams, WorkloadBuilder,
                               core_trace_count, grid_from_params,
                               make_leaf_spine, merge_params, metrics,
                               simulate, simulate_grid, simulate_seeds,
                               stack_knobs)
from repro.core.netsim.simulator import build_static, wl_arrays
from repro.core.netsim import simulate_core


@pytest.fixture(scope="module")
def small():
    topo = make_leaf_spine(8, 2, 2)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(8)), ring_size=4, chunk_bytes=1e6,
                   passes=1)
    return topo, b.build()


def _grid16(cfg: SimParams) -> list[SimParams]:
    """16 knob points spanning both gates and two Symphony knob axes."""
    out = []
    for sym in (False, True):
        for pq in (False, True):
            for tau in (0.2, 0.25):
                for k in (1e-2, 3e-2):
                    out.append(cfg._replace(
                        sym_on=sym, pq_on=pq,
                        sym=cfg.sym._replace(tau=tau, k=k)))
    return out

# ------------------------------------------------------- vmap consistency
def test_grid_bitwise_equals_per_point(small):
    """Acceptance: grid output slices == per-point simulate, bitwise, and
    the whole 16-point grid compiles the engine exactly once."""
    topo, wl = small
    cfg = SimParams(n_ticks=1500, window=8, record_every=10)
    cfgs = _grid16(cfg)
    assert len(cfgs) >= 16
    seeds = [0, 1]
    struct, knobs = grid_from_params(cfgs)

    c0 = core_trace_count()
    res = simulate_grid(topo, wl, struct, knobs, seeds, routing="ecmp")
    assert core_trace_count() - c0 == 1, "grid must be ONE compile"

    for i in (0, 3, 7, 10, 15):          # spot-check across the grid
        for j, seed in enumerate(seeds):
            one = simulate(topo, wl, cfgs[i], routing="ecmp", seed=seed)
            assert np.array_equal(np.asarray(res.finish_ticks)[i, j],
                                  np.asarray(one.finish_ticks)), (i, seed)
            assert np.array_equal(np.asarray(res.job_finish_ticks)[i, j],
                                  np.asarray(one.job_finish_ticks))
            assert np.array_equal(np.asarray(res.ts_throughput)[i, j],
                                  np.asarray(one.ts_throughput))
            assert np.array_equal(np.asarray(res.ts_alpha_max)[i, j],
                                  np.asarray(one.ts_alpha_max))


def test_grid_chunking_matches_unchunked(small):
    topo, wl = small
    cfg = SimParams(n_ticks=800, window=8, record_every=10)
    cfgs = [cfg._replace(sym_on=True, sym=cfg.sym._replace(k=k))
            for k in (1e-3, 3e-3, 1e-2, 3e-2, 1e-1)]
    struct, knobs = grid_from_params(cfgs)
    full = simulate_grid(topo, wl, struct, knobs, [0], routing="ecmp")
    chunked = simulate_grid(topo, wl, struct, knobs, [0], routing="ecmp",
                            chunk_knobs=2)   # 5 points -> 2+2+2 padded
    for a, b in zip(full, chunked):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_grid_chunking_prime_grid_partial_chunk(small):
    """Regression for the partial-final-chunk path: a prime-sized grid
    (K=7) never divides evenly, so every chunk_knobs in 2..6 ends with a
    ragged chunk that the executor pads by repeating the final knob point
    and slices back.  The padded lanes must not leak: every chunking must
    be bitwise-identical to the unchunked dispatch, without re-tracing."""
    topo, wl = small
    cfg = SimParams(n_ticks=600, window=8, record_every=10)
    ks = (1e-3, 2e-3, 3e-3, 5e-3, 1e-2, 3e-2, 1e-1)     # K = 7, prime
    cfgs = [cfg._replace(sym_on=True, sym=cfg.sym._replace(k=k))
            for k in ks]
    struct, knobs = grid_from_params(cfgs)
    full = simulate_grid(topo, wl, struct, knobs, [0, 1], routing="ecmp")
    for chunk in (2, 3, 4, 5, 6):
        c0 = core_trace_count()
        part = simulate_grid(topo, wl, struct, knobs, [0, 1], routing="ecmp",
                             chunk_knobs=chunk)
        # a chunk size is a new lane-axis shape -> at most ONE engine
        # trace, amortized over all chunks (the ragged final chunk is
        # padded to the same shape, so it reuses the compilation)
        assert core_trace_count() - c0 <= 1, chunk
        for a, b in zip(full, part):
            assert np.array_equal(np.asarray(a), np.asarray(b)), chunk
    c0 = core_trace_count()
    simulate_grid(topo, wl, struct, knobs, [0, 1], routing="ecmp",
                  chunk_knobs=3)
    assert core_trace_count() == c0, "repeated chunking must not re-trace"


def test_simulate_seeds_consistent_with_simulate(small):
    topo, wl = small
    cfg = SimParams(n_ticks=1500, window=8, record_every=10, sym_on=True)
    seeds = [0, 2, 5]
    batch = simulate_seeds(topo, wl, cfg, "ecmp", seeds)
    for j, seed in enumerate(seeds):
        one = simulate(topo, wl, cfg, routing="ecmp", seed=seed)
        assert np.array_equal(np.asarray(batch.finish_ticks)[j],
                              np.asarray(one.finish_ticks)), seed
        assert np.array_equal(np.asarray(batch.ts_throughput)[j],
                              np.asarray(one.ts_throughput)), seed


def test_knob_change_does_not_retrace(small):
    topo, wl = small
    cfg = SimParams(n_ticks=600, window=8, record_every=10)
    simulate(topo, wl, cfg, routing="ecmp", seed=0)   # prime the cache
    c0 = core_trace_count()
    for kmin, pmax, sym in [(40e3, 0.1, True), (60e3, 0.3, False),
                            (50e3, 0.2, True)]:
        simulate(topo, wl,
                 cfg._replace(red_kmin=kmin, red_pmax=pmax, sym_on=sym),
                 routing="ecmp", seed=0)
    assert core_trace_count() == c0, "knob values must not recompile"
    # a structural change DOES recompile
    simulate(topo, wl, cfg._replace(record_every=20), routing="ecmp", seed=0)
    assert core_trace_count() == c0 + 1


# ----------------------------------------------------------------- facade
def test_split_merge_roundtrip():
    cfg = SimParams(n_ticks=42, red_pmax=0.3, sym_on=True, pq_on=False,
                    share_policy="wfq", deploy="spine")
    struct, knobs = cfg.split()
    assert struct.n_ticks == 42 and struct.share_policy == "wfq"
    assert struct.deploy == "spine"
    merged = merge_params(struct, knobs)
    assert merged.n_ticks == 42
    assert float(merged.red_pmax) == pytest.approx(0.3)
    assert int(merged.sym_on) == 1 and int(merged.pq_on) == 0
    assert float(merged.sym.tau) == pytest.approx(cfg.sym.tau)


def test_legacy_simulate_core_signature(small):
    topo, wl = small
    cfg = SimParams(n_ticks=600, window=8, record_every=10)
    st = build_static(topo, wl, "balanced", seed=0, dt=cfg.dt,
                      deploy=cfg.deploy)
    legacy = simulate_core(st, wl_arrays(wl, cfg.dt), cfg,
                           jax.random.PRNGKey(0))
    struct, knobs = cfg.split()
    new = simulate_core(st, wl_arrays(wl, cfg.dt), struct, knobs,
                        jax.random.PRNGKey(0))
    assert np.array_equal(np.asarray(legacy.finish_ticks),
                          np.asarray(new.finish_ticks))


def test_grid_rejects_structural_mismatch(small):
    cfg = SimParams(n_ticks=600, window=8)
    with pytest.raises(ValueError, match="static structure"):
        grid_from_params([cfg, cfg._replace(window=16)])
    with pytest.raises(ValueError, match="empty"):
        grid_from_params([])


def test_stack_knobs_leading_axis():
    cfg = SimParams()
    ks = stack_knobs([cfg._replace(red_pmax=p).knobs() for p in (0.1, 0.2)])
    assert ks.red_pmax.shape == (2,)
    assert ks.sym.tau.shape == (2,)
    np.testing.assert_allclose(np.asarray(ks.red_pmax), [0.1, 0.2])


def test_pq_on_conflict_still_rejected(small):
    topo, wl = small
    cfg = SimParams(n_ticks=200, window=8, pq_on=True, share_policy="wfq")
    with pytest.raises(ValueError, match="pq_on"):
        simulate(topo, wl, cfg, routing="balanced", seed=0)
    # the grid executor enforces the same rule (a pq point would silently
    # override the wfq base policy at runtime otherwise)
    base = SimParams(n_ticks=200, window=8, share_policy="wfq")
    struct, knobs = grid_from_params([base, base._replace(pq_on=True)])
    with pytest.raises(ValueError, match="pq_on"):
        simulate_grid(topo, wl, struct, knobs, [0], routing="balanced")


def test_pq_gate_matches_pq_policy(small):
    """The traced pq_on gate must reproduce the static pq policy exactly."""
    topo, wl = small
    cfg = SimParams(n_ticks=1500, window=8, record_every=10)
    gate = simulate(topo, wl, cfg._replace(pq_on=True), "ecmp", seed=3)
    static = simulate(topo, wl, cfg._replace(share_policy="pq"), "ecmp",
                      seed=3)
    assert np.array_equal(np.asarray(gate.finish_ticks),
                          np.asarray(static.finish_ticks))


# -------------------------------------------------------------------- drr
def test_drr_registered_and_runs(small):
    assert "drr" in SHARE_POLICIES
    topo, wl = small
    cfg = SimParams(n_ticks=2500, window=8, record_every=10,
                    share_policy="drr")
    res = simulate(topo, wl, cfg, routing="balanced", seed=0)
    cct = metrics.cct_seconds(res, wl, cfg)[0]
    ideal = metrics.ideal_cct(wl, 0, 10e9 / 8)
    # balanced single-job ring: drr == equal split == ideal lockstep
    assert np.isfinite(cct) and cct < 1.6 * ideal


def test_drr_splits_port_equally_ignoring_weights():
    """Two chain jobs share one egress port: drr serves them 50/50 even
    with unequal wfq weights (quantum is per-flow, not per-weight)."""
    topo = make_leaf_spine(4, 2, 2)
    b = WorkloadBuilder()
    b.add_chain_job(pairs=[(0, 2)], steps=1, chunk_bytes=4e6)
    b.add_chain_job(pairs=[(1, 2)], steps=1, chunk_bytes=4e6)
    wl = b.build()
    cfg = SimParams(n_ticks=8000, window=8, record_every=10,
                    share_policy="drr", red_pmax=0.0)
    res = simulate(topo, wl, cfg, routing="balanced", seed=0,
                   job_weight=np.asarray([1.0, 3.0]))
    ft = np.asarray(res.finish_ticks).astype(float)
    # both at cap/2 until the first finishes -> equal finish times
    t_half = 4e6 / (1.25e9 * 0.5) / cfg.dt
    assert ft[0] == pytest.approx(t_half, rel=0.05)
    assert ft[1] == pytest.approx(t_half, rel=0.05)


def test_drr_selectable_from_registry():
    from benchmarks.common import build_scenario
    built = build_scenario("table1_ring", share_policy="drr", passes=1)
    assert built.cfg.share_policy == "drr"


# ------------------------------------------------------- benchmark caching
def test_cached_keys_on_config(tmp_path, monkeypatch):
    import benchmarks.common as common
    monkeypatch.setattr(common, "CACHE", tmp_path / "c.json")
    calls = []

    def make(v):
        def fn():
            calls.append(v)
            return {"v": v}
        return fn

    assert common.cached("x", make(1), config={"a": 1})["v"] == 1
    # same name, different overrides -> distinct key, recomputed
    assert common.cached("x", make(2), config={"a": 2})["v"] == 2
    # repeat of the first -> served from cache, no recompute
    assert common.cached("x", make(3), config={"a": 1})["v"] == 1
    assert calls == [1, 2]


def test_cached_discards_old_schema(tmp_path, monkeypatch):
    import json

    import benchmarks.common as common
    path = tmp_path / "c.json"
    path.write_text(json.dumps({"x": {"v": "stale"}}))   # pre-split cache
    monkeypatch.setattr(common, "CACHE", path)
    out = common.cached("x", lambda: {"v": "fresh"})
    assert out["v"] == "fresh"
    data = json.loads(path.read_text())
    assert data["__schema__"] == common.CACHE_SCHEMA
