"""Runtime tests: training drives loss down, checkpoint/restart resilience,
failure injection, elastic restore, data determinism, serving."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ParallelConfig, ServeConfig, TrainConfig
from repro.configs import registry
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build_model
from repro.runtime.serve import Request, ServeEngine
from repro.runtime.train import SimulatedFailure, StragglerMonitor, Trainer


def _tiny_cfg():
    return registry.get_config("h2o_danube_3_4b", smoke=True)


def _tcfg(tmp, steps=8, every=3):
    return TrainConfig(global_batch=4, seq_len=32, lr=1e-2, warmup_steps=2,
                       total_steps=steps, ckpt_every=every, ckpt_keep=2,
                       ckpt_dir=str(tmp), ckpt_async=False, seed=1)


def test_loss_decreases(tmp_path):
    cfg = _tiny_cfg()
    model = build_model(cfg)
    tr = Trainer(model, cfg, _tcfg(tmp_path, steps=30, every=100),
                 ParallelConfig(remat="none", scan_layers=False))
    rep = tr.run()
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first - 0.2, (first, last)


def test_checkpoint_restart_bit_exact(tmp_path):
    """Training 8 steps straight == 5 steps, restart, 3 more."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    t1 = Trainer(model, cfg, _tcfg(tmp_path / "a", steps=8, every=4),
                 ParallelConfig(remat="none", scan_layers=False))
    rep1 = t1.run()

    t2 = Trainer(model, cfg, _tcfg(tmp_path / "b", steps=8, every=4),
                 ParallelConfig(remat="none", scan_layers=False))
    rep2a = t2.run(steps=5)          # stops after step 4, ckpt at step 3
    t3 = Trainer(model, cfg, _tcfg(tmp_path / "b", steps=8, every=4),
                 ParallelConfig(remat="none", scan_layers=False))
    rep2b = t3.run(steps=8)          # resumes from ckpt
    # the resumed run replays steps 4.. and must match the straight run
    assert rep2b.losses[-1] == pytest.approx(rep1.losses[-1], rel=1e-4)


def test_failure_injection_recovers(tmp_path):
    cfg = _tiny_cfg()
    model = build_model(cfg)
    crashed = {"done": False}

    def injector(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise SimulatedFailure("node lost")

    tr = Trainer(model, cfg, _tcfg(tmp_path, steps=8, every=2),
                 ParallelConfig(remat="none", scan_layers=False),
                 failure_injector=injector)
    rep = tr.run()
    assert rep.restarts == 1
    assert np.isfinite(rep.final_loss)


def test_elastic_restore_different_sharding(tmp_path):
    """A checkpoint restores regardless of mesh: global arrays reshard."""
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
            "opt": {"m": np.ones((8, 8), np.float32)}}
    mgr.save(3, tree, {"step": 3})
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32),
            "opt": {"m": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
    restored, extra = mgr.restore(3, like)
    assert extra["step"] == 3
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_checkpoint_crc_detects_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    tree = {"w": np.ones((4, 4), np.float32)}
    mgr.save(1, tree, {"step": 1})
    victim = next((tmp_path / "step_00000001").glob("*.npy"))
    arr = np.load(victim)
    arr[0, 0] = 999.0
    np.save(victim, arr)
    with pytest.raises(IOError):
        mgr.restore(1, {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)})


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in range(5):
        mgr.save(s, {"w": np.zeros(3, np.float32)}, {"step": s})
    assert mgr.list_steps() == [3, 4]


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=8, seed=7)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    t1, l1 = d1.batch(11)
    t2, l2 = d2.batch(11)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(l1, l2)
    # labels are next tokens
    np.testing.assert_array_equal(t1[:, 1:], l1[:, :-1])
    # shards partition deterministically per (step, shard)
    a0, _ = d1.batch(5, shard=0, n_shards=2)
    a1, _ = d1.batch(5, shard=1, n_shards=2)
    assert a0.shape == (4, 16)
    assert not np.array_equal(a0, a1)


def test_straggler_monitor_flags_outlier():
    m = StragglerMonitor()
    for s in range(20):
        assert not m.observe(s, 0.1 + 0.001 * (s % 3))
    assert m.observe(20, 1.5)
    assert len(m.events) == 1


def test_serve_engine_batched(tmp_path):
    cfg = registry.get_config("mamba2_130m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, cfg, ServeConfig(batch=4, max_seq=64), params)
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 5).astype(np.int32), max_new_tokens=4))
    done = eng.run_until_drained(max_steps=200)
    assert len(done) == 6
    assert all(len(r.out) == 4 for r in done)
