"""Tests for the generalized staged netsim engine.

* Golden equivalence: the variable-hop engine must reproduce the seed
  2-tier/4-hop results bit-for-bit on the Table-1 scenario (constants below
  were captured from the pre-refactor monolithic simulator).
* Unit tests for the stage functions and share policies.
* Fat-tree link indexing / candidate-path correctness.
* End-to-end runs of the new topologies and collectives through the
  benchmark scenario registry.
"""
import jax
import numpy as np
import pytest

from repro.core.netsim import (SimParams, WorkloadBuilder, build_static,
                               link_domains, make_fat_tree, make_leaf_spine,
                               metrics, simulate)
from repro.core.netsim.simulator import wl_arrays
from repro.core.netsim import stages
from repro.core.netsim.stages import (make_ctx, init_state, select_routes,
                                      seg_global, wire_step)

# ---------------------------------------------------------------- golden
# Captured from the pre-refactor engine (monolithic simulate_core, fixed
# [F, 4] routes): Table-1 fabric, 4 rings of 8 over 32 hosts, 1 MB chunks,
# 2 back-to-back passes, seed 3.
GOLDEN_JOB = {"ecmp_base": 10757, "ecmp_sym": 7900,
              "balanced_sym": 2239, "ecmp_pq": 10303}
GOLDEN_FLOWS_ECMP_BASE = [
    9296, 7344, 7659, 8375, 8795, 9180, 9359, 9439, 10450, 10648, 10728,
    10601, 10268, 10348, 9887, 10228, 10658, 10757, 10754, 10205, 10011,
    10053, 10007, 10383, 9050, 9050, 9009, 8801, 8734, 9119, 9081, 9107]
GOLDEN_FLOWS_ECMP_SYM = [
    7853, 7891, 7769, 7877, 7837, 7864, 7698, 7900, 7845, 7894, 7802, 7889,
    7807, 7843, 7699, 7893, 7824, 7892, 7825, 7878, 7748, 7860, 7698, 7861,
    7853, 7877, 7764, 7877, 7747, 7835, 7692, 7891]


def _table1():
    topo = make_leaf_spine(32, 4, 4)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(32)), ring_size=8, chunk_bytes=1e6,
                   passes=2, barrier=False)
    return topo, b.build()


def test_golden_equivalence_table1():
    """Refactor preserves the seed engine bit-for-bit (sym on and off)."""
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64)
    base = simulate(topo, wl, cfg, routing="ecmp", seed=3)
    assert int(base.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_base"]
    assert np.asarray(base.finish_ticks).tolist() == GOLDEN_FLOWS_ECMP_BASE
    sym = simulate(topo, wl, cfg._replace(sym_on=True), routing="ecmp",
                   seed=3)
    assert int(sym.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_sym"]
    assert np.asarray(sym.finish_ticks).tolist() == GOLDEN_FLOWS_ECMP_SYM


@pytest.mark.slow
def test_golden_equivalence_balanced_and_pq():
    topo, wl = _table1()
    cfg = SimParams(n_ticks=20_000, window=64)
    bal = simulate(topo, wl, cfg._replace(sym_on=True), routing="balanced",
                   seed=3)
    assert int(bal.job_finish_ticks[0]) == GOLDEN_JOB["balanced_sym"]
    pq = simulate(topo, wl, cfg._replace(pq_on=True), routing="ecmp", seed=3)
    assert int(pq.job_finish_ticks[0]) == GOLDEN_JOB["ecmp_pq"]


# ----------------------------------------------------------- stage units
def _small_ctx(cfg=None, routing="balanced"):
    topo = make_leaf_spine(8, 2, 2)
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(8)), ring_size=4, chunk_bytes=1e6,
                   passes=1)
    wl = b.build()
    cfg = cfg or SimParams(n_ticks=100, window=8, record_every=10)
    st = build_static(topo, wl, routing, seed=0, dt=cfg.dt, deploy=cfg.deploy)
    return topo, wl, cfg, make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)


def test_wire_step_encoding_monotone():
    sps, phase, nph = 6, 0, 1
    ws = [int(wire_step(c, sps, phase, nph)) for c in range(18)]
    assert ws == sorted(ws) and len(set(ws)) == len(ws)
    # segment index advances every sps steps
    assert int(seg_global(5, 6, 0, 1)) == 0 and int(seg_global(6, 6, 0, 1)) == 1
    # phase 1 of a 2-phase job interleaves after phase 0 of the same pass
    assert int(seg_global(0, 6, 1, 2)) == 1 and int(seg_global(6, 6, 0, 2)) == 2


def test_stage_starts_gates_on_ring_dependency():
    _, wl, cfg, ctx = _small_ctx()
    state = init_state(ctx, jax.random.PRNGKey(0))
    starts = stages.stage_starts(ctx, state, 0)
    # step 0 can start everywhere at tick 0
    assert bool(np.asarray(starts.can).all())
    assert np.asarray(starts.step_of)[:, 0].tolist() == [0] * wl.n_flows
    # step 1 is blocked until the predecessor's step-0 send makes progress
    state1 = state._replace(next_step=starts.next_step,
                            step_of=starts.step_of, sent=starts.sent)
    starts1 = stages.stage_starts(ctx, state1, 1)
    assert not bool(np.asarray(starts1.can).any())
    # completing the predecessor's chunk unblocks step 1
    state2 = state1._replace(sent=jax.numpy.full_like(starts.sent, 1e6))
    starts2 = stages.stage_starts(ctx, state2, 2)
    assert bool(np.asarray(starts2.can).all())


def test_stage_queues_red_profile():
    _, _, cfg, ctx = _small_ctx()
    cap = np.asarray(ctx.st.cap)
    offered = np.zeros_like(cap)
    offered[0] = cap[0] * 2.0          # 2x overload on one access link
    offered[-1] = 1e30                 # null link must stay empty
    q, p_red = stages.stage_queues(ctx, cfg, np.zeros_like(cap), offered)
    q = np.asarray(q)
    assert q[0] == pytest.approx(cap[0] * cfg.dt)
    assert q[-1] == 0.0
    # RED profile: 0 below kmin, pmax above kmax
    q2 = np.zeros_like(cap)
    q2[1] = cfg.red_kmax * 2
    _, p2 = stages.stage_queues(ctx, cfg, q2, np.zeros_like(cap))
    assert float(np.asarray(p2)[1]) == pytest.approx(cfg.red_pmax)
    assert float(np.asarray(p2)[0]) == 0.0


def test_select_routes_per_step_rehash():
    _, wl, cfg, ctx = _small_ctx(routing="balanced")
    # static: every instance of a flow uses the flow's route
    r_static = np.asarray(select_routes(ctx, np.zeros(ctx.FW, np.int32),
                                        per_step_ecmp=False))
    assert (r_static == np.asarray(ctx.iroute_static)).all()
    # per-step: routes always come from the flow's candidate table
    table = np.asarray(ctx.st.path_table)
    for step in (0, 1, 7):
        r = np.asarray(select_routes(
            ctx, np.full(ctx.FW, step, np.int32), per_step_ecmp=True))
        for i in range(0, ctx.FW, ctx.W):
            f = i // ctx.W
            assert any((r[i] == table[f, p]).all()
                       for p in range(table.shape[1]))
    # different steps re-roll at least one inter-ToR flow's path
    r0 = np.asarray(select_routes(ctx, np.zeros(ctx.FW, np.int32), True))
    r1 = np.asarray(select_routes(ctx, np.ones(ctx.FW, np.int32), True))
    assert (r0 != r1).any()


def test_share_policies_conserve_capacity():
    _, _, cfg, ctx = _small_ctx()
    state = init_state(ctx, jax.random.PRNGKey(0))
    starts = stages.stage_starts(ctx, state, 0)
    inst = stages.instance_view(ctx, starts, state, cfg.mtu, False)
    cap = np.asarray(ctx.st.cap)
    for name, fn in stages.SHARE_POLICIES.items():
        shr = fn(ctx, cfg, inst, 0)
        eff = np.asarray(shr.eff)
        assert (eff >= 0).all(), name
        # delivered load on any link never exceeds its capacity
        load = np.zeros_like(cap)
        np.add.at(load, np.asarray(inst.flat_links),
                  np.repeat(eff, ctx.H))
        assert (load[:-1] <= cap[:-1] * (1 + 1e-5)).all(), name


def test_wfq_weights_split_bottleneck():
    """Two single-flow jobs through one port: weight 3 gets ~3x bandwidth."""
    topo = make_leaf_spine(4, 2, 2)
    b = WorkloadBuilder()
    b.add_chain_job(pairs=[(0, 2)], steps=1, chunk_bytes=4e6)
    b.add_chain_job(pairs=[(1, 2)], steps=1, chunk_bytes=4e6)
    wl = b.build()
    # red_pmax=0 disables rate-control noise: shares are purely weighted-fair
    cfg = SimParams(n_ticks=8000, window=8, record_every=10,
                    share_policy="wfq", red_pmax=0.0)
    res = simulate(topo, wl, cfg, routing="balanced", seed=0,
                   job_weight=np.asarray([1.0, 3.0]))
    ft = np.asarray(res.finish_ticks).astype(float)
    assert ft[1] < ft[0]
    # heavy job saturates 3/4 of the port until it finishes ...
    t_heavy = 4e6 / (1.25e9 * 0.75) / cfg.dt
    assert ft[1] == pytest.approx(t_heavy, rel=0.05)
    # ... then the light job (1/4 share so far) takes the whole port
    rem = 4e6 - ft[1] * cfg.dt * 1.25e9 * 0.25
    t_light = ft[1] + rem / 1.25e9 / cfg.dt
    assert ft[0] == pytest.approx(t_light, rel=0.05)


# --------------------------------------------------- fat-tree link table
def test_fat_tree_link_indexing_disjoint_and_complete():
    ft = make_fat_tree(n_pods=2, tors_per_pod=2, spines_per_pod=2,
                       hosts_per_tor=2, n_cores=4)
    H, T, S, P, C = 8, 4, 2, 2, 4
    assert ft.n_hosts == H and ft.n_tors == T
    ids = []
    ids += [ft.acc_up(h) for h in range(H)]
    ids += [ft.acc_down(h) for h in range(H)]
    ids += [ft.uplink(t, s) for t in range(T) for s in range(S)]
    ids += [ft.downlink(p, s, p * 2 + tl) for p in range(P)
            for s in range(S) for tl in range(2)]
    ids += [ft.spine_up(p, s, s * 2 + j) for p in range(P)
            for s in range(S) for j in range(2)]
    ids += [ft.core_down(c, p) for c in range(C) for p in range(P)]
    ids = np.asarray(ids, np.int64)
    # the tiers tile [0, L) exactly once
    assert sorted(ids.tolist()) == list(range(ft.n_links))
    assert ft.link_switch.shape[0] == ft.n_links
    assert ft.switch_level.shape[0] == T + P * S + C


def test_fat_tree_candidate_paths_inter_pod():
    ft = make_fat_tree(n_pods=2, tors_per_pod=2, spines_per_pod=2,
                       hosts_per_tor=2, n_cores=4)
    paths, n_paths = ft.candidate_paths(np.asarray([0]), np.asarray([4]))
    assert int(n_paths[0]) == 4          # one candidate per core
    for c in range(4):
        s = c // ft.cores_per_spine
        expect = [ft.acc_up(0), ft.uplink(0, s), ft.spine_up(0, s, c),
                  ft.core_down(c, 1), ft.downlink(1, s, 2), ft.acc_down(4)]
        assert paths[0, c].tolist() == [int(x) for x in expect]
    # intra-pod inter-ToR: spine fan-out, core hops null-padded
    p2, n2 = ft.candidate_paths(np.asarray([0]), np.asarray([2]))
    assert int(n2[0]) == 2
    null = ft.n_links
    assert p2[0, 0].tolist() == [int(ft.acc_up(0)), int(ft.uplink(0, 0)),
                                 int(ft.downlink(0, 0, 1)), null, null,
                                 int(ft.acc_down(2))]


def test_link_domains_deploy_tiers():
    topo = make_leaf_spine(8, 2, 2)
    dom, D = link_domains(topo, "tor")
    assert D == 2
    assert dom[topo.acc_down(np.arange(8))].tolist() == [0, 0, 0, 0,
                                                         1, 1, 1, 1]
    assert int(dom[topo.uplink(1, 0)]) == 1
    assert int(dom[topo.downlink(0, 1)]) == D        # spine egress excluded
    assert int(dom[topo.acc_up(0)]) == D             # host NIC excluded
    dom_all, D_all = link_domains(topo, "all")
    assert D_all == 4
    assert int(dom_all[topo.downlink(1, 0)]) == 2 + 1   # spine 1 compacted
    dom_sp, D_sp = link_domains(topo, "spine")
    assert D_sp == 2
    assert int(dom_sp[topo.uplink(0, 0)]) == D_sp    # ToR egress excluded
    assert int(dom_sp[topo.downlink(1, 1)]) == 1
    with pytest.raises(ValueError):
        link_domains(topo, "nowhere")


# ------------------------------------------------------ workload builders
def test_max_segments_padded_and_validated():
    b = WorkloadBuilder(max_segments=5)
    b.add_ring_job(hosts=list(range(4)), ring_size=4, chunk_bytes=2e6,
                   passes=2)
    wl = b.build()
    assert wl.chunk_sched.shape == (1, 5)
    assert wl.chunk_sched[0].tolist() == [2e6] * 5   # padded with last value
    b2 = WorkloadBuilder(max_segments=1)
    b2.add_ring_job(hosts=list(range(4)), ring_size=4, chunk_bytes=2e6,
                    passes=2)
    with pytest.raises(ValueError):
        b2.build()


def test_halving_doubling_schedule_shape():
    b = WorkloadBuilder()
    b.add_halving_doubling_job(hosts=list(range(8)), chunk_bytes=8e6)
    wl = b.build()
    assert wl.n_phases[0] == 6                       # 2 * log2(8)
    assert wl.n_flows == 6 * 8                       # one slot per (node, phase)
    np.testing.assert_allclose(
        wl.chunk_sched[0], [4e6, 2e6, 1e6, 1e6, 2e6, 4e6])
    # every slot runs exactly one step per pass, self-gated
    assert (wl.steps_per_seg == 1).all()
    assert (wl.pred == np.arange(wl.n_flows)).all()


def test_ideal_cct_multi_phase():
    b = WorkloadBuilder()
    b.add_hierarchical_job(hosts=list(range(8)), group_size=4,
                           chunk_bytes=4e6)
    wl = b.build()
    # 3 steps x V/4 local RS + 2 steps x V/8 leader ring + 3 x V/4 local AG
    expect = (3 * 1e6 + 2 * 0.5e6 + 3 * 1e6) / 1.25e9
    assert metrics.ideal_cct(wl, 0, 1.25e9) == pytest.approx(expect)


# ----------------------------------------------- registry / end-to-end
def test_fat_tree_and_halving_doubling_through_registry():
    """Acceptance: 3-tier fat-tree + halving-doubling end-to-end via the
    scenario registry, finishing within 2x of the lockstep bound under
    balanced routing."""
    from benchmarks.common import build_scenario
    for name, kw in [
        ("fat_tree_ring", dict(chunk=5e5, passes=1)),
        ("fat_tree_halving_doubling", dict(chunk=1e6)),
        ("hierarchical_tor", dict(n_hosts=16, n_tors=2, n_spines=2,
                                  chunk=2e6, passes=1)),
    ]:
        built = build_scenario(name, **kw)
        res = jax.block_until_ready(
            simulate(built.topo, built.wl, built.cfg, routing="balanced",
                     seed=0))
        cct = metrics.cct_seconds(res, built.wl, built.cfg)[0]
        ideal = metrics.ideal_cct(built.wl, 0, 1.25e9)
        assert np.isfinite(cct), name
        assert cct < 2.0 * ideal + 1e-3, (name, cct, ideal)


def test_fat_tree_core_oversubscription_slows_inter_pod():
    from benchmarks.common import build_scenario
    ccts = {}
    # at os=8 each core link (8 host-loads over 2 cores at os=1) drops to
    # half a line rate, so inter-pod ring steps take ~2x
    for os_core in (1.0, 8.0):
        built = build_scenario("fat_tree_ring", chunk=5e5, passes=1,
                               core_oversubscription=os_core)
        res = simulate(built.topo, built.wl, built.cfg, routing="balanced",
                       seed=0)
        ccts[os_core] = metrics.cct_seconds(res, built.wl, built.cfg)[0]
    assert ccts[8.0] > ccts[1.0] * 1.4, ccts
