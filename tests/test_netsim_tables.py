"""Property tests for the packed per-instance route tables.

`params.pack_route_tables` materializes dense ``[FW]``-leading copies of
every table the tick kernel used to *gather* from, so the tiled Pallas
kernel can BlockSpec-stream them and lower gather-free.  These tests pin
the packing contract: the packed slabs must round-trip **exactly** to the
reference ``table[index]`` gathers across ECMP fan-outs, topologies, and
non-dividing block tilings — plus the window-kernel state-donation and
the benchmark-trajectory dedupe contracts that ride on the same PR.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.netsim import (SimParams, WorkloadBuilder, build_static,
                               make_fat_tree, make_leaf_spine)
from repro.core.netsim.params import (PackedTables, pack_route_tables,
                                      plan_tiling)
from repro.core.netsim.simulator import wl_arrays
from repro.core.netsim.stages import init_state, make_ctx


def _ring_wl(n_hosts, ring):
    b = WorkloadBuilder()
    b.add_ring_job(hosts=list(range(n_hosts)), ring_size=ring,
                   chunk_bytes=2e5, passes=1, barrier=False)
    return b.build()


def _leaf_spine(n_spines):
    return make_leaf_spine(8, 2, n_spines), _ring_wl(8, 4)


def _fat_tree_multipod():
    topo = make_fat_tree(n_pods=2, tors_per_pod=2, spines_per_pod=2,
                         hosts_per_tor=2)
    return topo, _ring_wl(topo.n_hosts, topo.n_hosts)


TOPOS = [lambda: _leaf_spine(1), lambda: _leaf_spine(2),
         lambda: _leaf_spine(4), _fat_tree_multipod]
TOPO_IDS = ["leaf_spine_p1", "leaf_spine_p2", "leaf_spine_p4",
            "fat_tree_multipod"]


def _ctx_for(build, window=8):
    topo, wl = build()
    cfg = SimParams(n_ticks=100, window=window)
    st = build_static(topo, wl, "ecmp", seed=3, dt=cfg.dt,
                      deploy=cfg.deploy)
    return st, make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window), cfg


# ------------------------------------------- packing == reference gathers
@pytest.mark.parametrize("build", TOPOS, ids=TOPO_IDS)
def test_packed_tables_match_reference_gathers(build):
    """Every packed slab equals the gather it replaces, bitwise, on the
    real inst_flow/inst_job layout (row f*W + w holds flow f's table)."""
    st, ctx, _ = _ctx_for(build)
    t = ctx.tables
    fl = np.asarray(ctx.inst_flow)
    jb = np.asarray(ctx.inst_job)
    ref = {
        "routes": np.asarray(st.routes)[fl],
        "route_dom": np.asarray(st.link_dom[st.routes])[fl],
        "cand": np.asarray(st.path_table)[fl],
        "cand_dom": np.asarray(st.link_dom[st.path_table])[fl],
        "n_paths": np.asarray(st.n_paths)[fl],
        "chunk": np.asarray(ctx.wl.chunk_sched)[jb],
    }
    for f in PackedTables._fields:
        assert np.array_equal(np.asarray(getattr(t, f)), ref[f]), f


def test_ecmp_fanout_coverage():
    """The parametrized topologies really cover P in {1, 2, 4}."""
    fanouts = set()
    for build in TOPOS:
        st, _, _ = _ctx_for(build)
        fanouts.add(int(st.path_table.shape[1]))
    assert {1, 2, 4} <= fanouts, f"P coverage only {sorted(fanouts)}"


@pytest.mark.parametrize("build", [lambda: _leaf_spine(4),
                                   _fat_tree_multipod],
                         ids=["leaf_spine_p4", "fat_tree_multipod"])
def test_iota_select_matches_candidate_gather(build):
    """The kernel's candidate-plane iota-select over the streamed slab
    equals the ``path_table[inst, choice]`` gather it replaced, for
    arbitrary in-range per-instance choices."""
    from repro.kernels.netsim_tick.kernel import _onehot_plane

    st, ctx, _ = _ctx_for(build)
    t = ctx.tables
    FW = ctx.FW
    rng = np.random.default_rng(7)
    n_p = np.asarray(t.n_paths)
    choice = jnp.asarray(rng.integers(0, 2**31 - 1, FW) % n_p, jnp.int32)
    sel = np.asarray(_onehot_plane(t.cand, choice))
    sel_dom = np.asarray(_onehot_plane(t.cand_dom, choice))
    fl = np.asarray(ctx.inst_flow)
    ch = np.asarray(choice)
    assert np.array_equal(sel, np.asarray(st.path_table)[fl, ch])
    assert np.array_equal(sel_dom,
                          np.asarray(st.link_dom[st.path_table])[fl, ch])


@pytest.mark.parametrize("blk", [24, 40])
def test_edge_padded_blocks_reconstruct(blk):
    """Non-dividing blk: the edge-padded slab, sliced block-by-block and
    masked by the scalar-prefetched valid counts, reconstructs every
    packed table exactly (edge padding never invents out-of-range rows
    in the valid region)."""
    from repro.kernels.netsim_tick.kernel import _edge_pad

    st, ctx, _ = _ctx_for(lambda: _leaf_spine(2))
    FW = ctx.FW
    nb = -(-FW // blk)
    assert FW % blk != 0, "want a non-dividing blk for this test"
    nvalid = [min(blk, FW - i * blk) for i in range(nb)]
    for f in PackedTables._fields:
        x = np.asarray(getattr(ctx.tables, f))
        padded = np.asarray(_edge_pad(jnp.asarray(x), nb * blk - FW))
        got = np.concatenate([padded[i * blk: i * blk + nvalid[i]]
                              for i in range(nb)])
        assert np.array_equal(got, x), f"{f} blk={blk}"


def test_plan_tiling_contract():
    st, ctx, _ = _ctx_for(lambda: _leaf_spine(2))
    FW = ctx.FW
    assert plan_tiling(FW, None, "scatter", 1) is None
    assert plan_tiling(FW, 16, "onehot", 1) == 16
    # tick_window > 1 routes through the window kernel: tiling normalizes
    assert plan_tiling(FW, 16, "onehot", 5) is None
    # blk >= FW normalizes to untiled
    assert plan_tiling(FW, FW, "onehot", 1) is None
    with pytest.raises(ValueError, match="onehot"):
        plan_tiling(FW, 16, "scatter", 1)
    with pytest.raises(ValueError, match="blk"):
        plan_tiling(FW, 0, "onehot", 1)


# ------------------------------------------------ window state donation
def test_window_kernel_donates_state():
    """The multi-tick window dispatch aliases all N_STATE carried state
    inputs to their same-shaped outputs, so a record period of windows
    updates state in place instead of copying it once per window."""
    from repro.core.netsim.params import merge_params
    from repro.kernels.netsim_tick.ops import engine_window_fused
    from repro.kernels.netsim_tick.window import N_STATE

    topo, wl = _leaf_spine(2)[0], _ring_wl(8, 4)
    cfg = SimParams(n_ticks=100, window=8, sym_on=True, backend="pallas",
                    tick_window=5)
    st = build_static(topo, wl, "ecmp", seed=3, dt=cfg.dt,
                      deploy=cfg.deploy)
    ctx = make_ctx(st, wl_arrays(wl, cfg.dt), cfg.window)
    struct, knobs = cfg.split()
    ecfg = merge_params(struct, knobs)
    state = init_state(ctx, jax.random.PRNGKey(0))

    jx = jax.make_jaxpr(
        lambda s, t: engine_window_fused(ctx, ecfg, s, t, 5))(
            state, jnp.int32(0))
    aliases = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                aliases.append(eqn.params.get("input_output_aliases"))
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    walk(v.jaxpr)

    walk(jx.jaxpr)
    assert len(aliases) == 1, f"expected 1 pallas_call, got {len(aliases)}"
    got = dict(aliases[0])
    assert got == {i: i for i in range(N_STATE)}, got


# ------------------------------------------- benchmark trajectory dedupe
def test_bench_trajectory_dedupe_by_sha_mode_variant(tmp_path, monkeypatch):
    """Re-running netsim_perf on the same commit replaces that commit's
    trajectory entries (per variant) instead of appending duplicates;
    different variants and shas coexist, and legacy entries without a
    variant field read as pallas_tuned."""
    from benchmarks import netsim_perf as npf

    bench = tmp_path / "BENCH_netsim.json"
    legacy = {"sha": "old1", "mode": "quick", "ticks_per_s": 10}
    bench.write_text(json.dumps(
        {"schema": npf.BENCH_SCHEMA, "trajectory": [legacy]}))
    monkeypatch.setattr(npf, "BENCH_FILE", bench)
    monkeypatch.setattr(npf, "_git_sha", lambda: "abc1234")
    monkeypatch.setattr(npf, "_mode", lambda: "quick")
    result = {"grid_lanes": 16,
              "backends": {"xla": {"ticks_per_s": 100},
                           "pallas_tuned": {"ticks_per_s": 90},
                           "pallas_gatherfree": {"ticks_per_s": 80}}}

    data = npf.write_bench(result)
    traj = data["trajectory"]
    assert len(traj) == 3            # legacy + tuned + gatherfree
    assert traj[0] == legacy         # other shas untouched
    key = {(e["sha"], e.get("variant", "pallas_tuned")) for e in traj}
    assert ("abc1234", "pallas_tuned") in key
    assert ("abc1234", "pallas_gatherfree") in key

    # same sha+mode+variant again: replaced in place, not duplicated
    result["backends"]["pallas_gatherfree"]["ticks_per_s"] = 85
    traj = npf.write_bench(result)["trajectory"]
    assert len(traj) == 3
    gf = [e for e in traj if e.get("variant") == "pallas_gatherfree"]
    assert len(gf) == 1 and gf[0]["ticks_per_s"] == 85

    # a legacy pallas_tuned entry on the SAME sha is replaced too (the
    # missing variant field reads as pallas_tuned)
    legacy_same = {"sha": "abc1234", "mode": "quick", "ticks_per_s": 1}
    data = json.loads(bench.read_text())
    data["trajectory"].append(legacy_same)
    bench.write_text(json.dumps(data))
    traj = npf.write_bench(result)["trajectory"]
    tuned = [e for e in traj
             if e["sha"] == "abc1234"
             and e.get("variant", "pallas_tuned") == "pallas_tuned"]
    assert len(tuned) == 1 and tuned[0]["ticks_per_s"] == 90
