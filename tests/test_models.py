"""Per-architecture smoke tests (reduced configs): forward + train step +
decode on CPU, asserting shapes and finiteness; param-count formula check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, param_count
from repro.configs import registry
from repro.models import build_model
from repro.models.params import count_params
from repro.optim.adamw import adamw_update, init_opt_state

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module", params=registry.ARCHS)
def arch(request):
    return request.param


def _forward(model, cfg, params, B=2, S=64):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
        return model.apply(params, tokens, frames), tokens
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(S)[:, None], (B, S, 3)).astype(
            jnp.int32)
        emb = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.bfloat16)
        return model.apply(params, positions=pos, embeds=emb), tokens
    return model.apply(params, tokens), tokens


def test_smoke_forward_and_decode(arch):
    cfg = registry.get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    (logits, aux), tokens = _forward(model, cfg, params)
    assert logits.shape[:2] == (2, 64)
    assert logits.shape[-1] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # decode
    B = 2
    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
        enc = model.encode(params, frames)
        cache = model.init_cache(params, enc, max_seq=32)
    else:
        cache = model.init_cache(B, 32)
    lg, cache2 = model.decode_step(params, cache, tokens[:, :1],
                                   jnp.zeros(B, jnp.int32))
    assert lg.shape[0] == B
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())


def test_smoke_train_step(arch):
    """One SGD-ish step must run and produce finite grads/params."""
    cfg = registry.get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    tcfg = TrainConfig(global_batch=2, seq_len=32, lr=1e-3, total_steps=10,
                       warmup_steps=2)
    opt = init_opt_state(params, tcfg)

    def loss_fn(p):
        (logits, aux), tokens = _forward(model, cfg, p, B=2, S=32)
        labels = jnp.roll(tokens, -1, axis=1)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   -1)[..., 0].astype(jnp.float32)
        return (lse - gold).mean() + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    new_params, opt, metrics = adamw_update(params, grads, opt, tcfg)
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
    assert np.isfinite(float(metrics["grad_norm"]))


def test_param_count_formula_matches_built(arch):
    """Closed-form param_count == actual built tree (tp=1, no padding)."""
    cfg = registry.get_config(arch, smoke=True)
    model = build_model(cfg)
    built = count_params(model.param_spec())
    formula = param_count(cfg)
    assert built == formula, (arch, built, formula)


def test_decode_matches_prefill_gqa():
    """Cached decode == teacher-forced forward, token by token."""
    cfg = registry.get_config("h2o_danube_3_4b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    (full_logits, _), _ = model.apply(params, tokens), None
    cache = model.init_cache(B, 32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        atol=0.15, rtol=0.1)   # bf16 params, different contraction orders


def test_decode_matches_prefill_ssm():
    cfg = registry.get_config("mamba2_130m", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    (full_logits, _) = model.apply(params, tokens)
    cache = model.init_cache(B, 32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    # bf16 params + different contraction orders (chunked SSD vs per-token
    # recurrence): a handful of near-tie logits can differ by ~0.2
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        atol=0.3, rtol=0.15)


def test_mla_decode_matches_prefill():
    cfg = registry.get_config("minicpm3_4b", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 12
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    (full_logits, _) = model.apply(params, tokens)
    cache = model.init_cache(B, 16)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        atol=0.2, rtol=0.1)


def test_sliding_window_masks_old_tokens():
    """SWA: attention output at position t must not depend on tokens
    older than the window."""
    cfg = registry.get_config("h2o_danube_3_4b", smoke=True)  # window 64
    from repro.models.attention import ref_attention
    B, S, H, D = 1, 128, 2, 16
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    o1 = ref_attention(q, k, v, pos, pos, window=64)
    # perturb tokens outside every window of the last position
    k2 = k.at[:, :32].set(jax.random.normal(jax.random.PRNGKey(3),
                                            (B, 32, H, D)))
    v2 = v.at[:, :32].set(jax.random.normal(jax.random.PRNGKey(4),
                                            (B, 32, H, D)))
    o2 = ref_attention(q, k2, v2, pos, pos, window=64)
    np.testing.assert_allclose(np.asarray(o1[:, 96:]),
                               np.asarray(o2[:, 96:]), atol=1e-6)
