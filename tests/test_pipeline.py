"""GPipe pipeline-parallel correctness (subprocess, 4 stages)."""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.pipeline import run_pipelined

mesh = make_mesh((4,), ("pod",))
key = jax.random.PRNGKey(0)
# 4 stages x 2 layers each: y = tanh(x @ w) per layer
W = jax.random.normal(key, (4, 2, 16, 16)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))

def stage_fn(wstack, h):
    # wstack: [1, 2, 16, 16] local slice of stages
    for i in range(wstack.shape[1]):
        h = jnp.tanh(h @ wstack[0, i])
    return h

got = np.asarray(run_pipelined(mesh, stage_fn, W, x, microbatches=4))

ref = np.asarray(x)
Wn = np.asarray(W)
for s in range(4):
    for i in range(2):
        ref = np.tanh(ref @ Wn[s, i])
np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)

# HLO must show the inter-stage ppermute ring
lw = jax.jit(lambda w, v: run_pipelined(mesh, stage_fn, w, v,
                                        microbatches=4)).lower(W, x)
assert "collective-permute" in lw.compile().as_text()
print("PIPELINE_OK")
"""


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PIPELINE_OK" in r.stdout
